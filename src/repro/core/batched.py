"""Vectorized fleet-prediction engine: traces against many devices.

The serving question Habitat answers is "from the one device you own, rank
every device you could buy" (Sec. 5.3) — at production scale that is one
trace predicted against *dozens* of destinations per request.  The per-op
Python loop in the original ``HabitatPredictor.predict_trace`` pays the
interpreter cost once per (op, device) pair; this module pays it once per
trace — and, for fleet-wide what-if sweeps (many batch sizes / model
variants x many devices), once per *stack* of traces.

The pipeline is fully array-shaped:

  * kernel-alike ops   -> ``wave_scaling.scale_times_vec`` fills the whole
                          (n_ops x n_devices) grid in one NumPy expression,
  * kernel-varying ops -> one batched MLP inference per kind covering *all*
                          destinations at once (features tiled device-major),
                          falling back to a vectorized Paleo-style roofline
                          when no MLP is available for a kind.

``FleetPrediction`` keeps the per-(op, device) grid so per-kind breakdowns
and per-device totals are both O(1) array reductions afterwards.

Multi-trace layer: :func:`stack_traces` concatenates several traces into a
:class:`RaggedTraceArrays` (one structure-of-arrays with segment offsets),
:func:`predict_sweep` fills the whole (total_ops x n_devices) grid in one
pass — segment-aware wave scaling handles per-trace origins, and when all
four op-kind MLPs share an architecture the kernel-varying rows can be
scored by ONE fused Pallas launch (:class:`FusedMLPScorer`) instead of
four jitted per-kind forwards.  Row i of the resulting
:class:`SweepPrediction` equals ``predict_trace_batch`` on trace i alone:
bitwise on the wave-scaling and analytical paths, and to float32-forward
tolerance (~1e-6) on trained-MLP rows, whose jitted batches pad to
different shapes in the two spellings.
"""

from __future__ import annotations

import dataclasses
import os
import threading
from collections import OrderedDict
from types import SimpleNamespace
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import dataset as dataset_mod
from repro.core import devices, wave_scaling
from repro.core.devices import DeviceArrays, DeviceSpec
from repro.core.trace import TraceArrays, TrackedTrace

#: Paleo-fallback efficiencies, matching ``predictor._analytical_ms``.
_EFF_COMPUTE = (0.50, 0.70)   # (kernel-alike, kernel-varying)
_EFF_MEMORY = (0.82, 0.75)


def _env_num(name: str, default, cast):
    """A numeric knob from the environment, falling back on bad input.

    The ONE parse-or-keep-the-default policy for every env knob in the
    engine and the serve layer (cache bounds here, the split-planner
    seeds in ``serve.service``): a malformed or negative override must
    not take a worker down — the documented default applies instead."""
    raw = os.environ.get(name)
    if raw is None:
        return default
    try:
        value = cast(raw)
    except ValueError:
        return default
    return value if value >= 0 else default


def env_int(name: str, default: int) -> int:
    return _env_num(name, default, int)


def env_float(name: str, default: float) -> float:
    return _env_num(name, default, float)


class _DispatchCounters:
    """Process-wide MLP scorer-dispatch accounting.

    ``fused`` counts one-launch scorer calls (``fused_mlp_score`` /
    ``fused_mlp_score_rows``); ``per_kind`` counts individual per-kind
    ``predict_ms`` forwards.  The dispatch-count model of the hot path
    (README "Performance") is asserted against these by the tests and
    ``benchmarks/bench_dispatch.py`` — a refactor that silently
    re-introduces a per-kind loop fails the counter gates, not just a
    timing gate."""

    def __init__(self):
        self._lock = threading.Lock()
        self.fused = 0
        self.per_kind = 0

    def bump(self, which: str, n: int = 1) -> None:
        with self._lock:
            setattr(self, which, getattr(self, which) + n)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {"fused": self.fused, "per_kind": self.per_kind}

    def reset(self) -> None:
        with self._lock:
            self.fused = 0
            self.per_kind = 0


#: dispatch accounting for every MLP scoring path (see class docstring)
SCORER_DISPATCHES = _DispatchCounters()


class _WaveFactorCache:
    """Cross-stack LRU of t-independent wave-scaling factor grids.

    The factor grid (``wave_scaling.wave_factor_vec``) is a pure function
    of the kernel-alike op arrays and the destination fleet — it carries
    all of the pow-heavy work, while the final ``t * factor`` combine is
    a single multiply.  PR 4 cached it per ``RaggedTraceArrays``, so the
    factor died with its stack: repeat single-trace ``predict()`` traffic
    and freshly-restacked sweeps recomputed it from scratch.  This cache
    is module-level and keyed by

        (content token, fleet names, exact, overhead-model token)

    where the content token is the tuple of trace fingerprints (a single
    trace is the 1-tuple, so ``predict()`` and a 1-trace sweep SHARE the
    entry).  Every entry stores the ``DeviceArrays`` instance AND the
    origin ``DeviceSpec`` tuple it was minted against; a lookup only
    hits when the caller presents the *same* destination instance
    (``devices.as_arrays`` memoizes one instance per distinct spec
    tuple, so identity implies spec content) and value-equal origin
    specs (the fingerprint names the origin but does not hash its
    numbers, so a replaced registry entry must invalidate).  Either way
    a same-named device with different specs can never be served a
    stale factor — the stale entry is simply overwritten on recompute.

    Bounded by entry count AND bytes (env ``REPRO_FACTOR_CACHE_ENTRIES``
    / ``REPRO_FACTOR_CACHE_BYTES``, defaults 64 entries / 128 MiB);
    thread-safe (the serving layer's coalescing leaders are concurrent
    short-lived threads)."""

    def __init__(self, capacity: Optional[int] = None,
                 max_bytes: Optional[int] = None):
        self.capacity = (env_int("REPRO_FACTOR_CACHE_ENTRIES", 64)
                         if capacity is None else capacity)
        self.max_bytes = (env_int("REPRO_FACTOR_CACHE_BYTES", 128 << 20)
                          if max_bytes is None else max_bytes)
        self._data: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        self._lock = threading.Lock()
        self._total_bytes = 0
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.evictions = 0

    @staticmethod
    def _entry_bytes(factor: np.ndarray, overheads) -> int:
        n = factor.nbytes
        if overheads is not None:
            n += overheads[0].nbytes + overheads[1].nbytes
        return n

    def get(self, key: Tuple, da: DeviceArrays, origins: Tuple):
        """(factor, overheads) when warm for this exact ``DeviceArrays``
        instance and value-equal origin specs, else None (counted as a
        miss)."""
        return self._lookup(key, da, origins, count_miss=True)

    def peek(self, key: Tuple, da: DeviceArrays, origins: Tuple):
        """Like :meth:`get` but a cold probe is NOT counted as a miss:
        masked sweeps probe opportunistically and by design never insert
        on a miss (a partial fill must not pay the full-grid factor
        build), so counting those probes would poison the hit ratio the
        shutdown log tells operators to tune bounds by."""
        return self._lookup(key, da, origins, count_miss=False)

    def _lookup(self, key: Tuple, da: DeviceArrays, origins: Tuple,
                count_miss: bool):
        with self._lock:
            entry = self._data.get(key)
            if entry is not None and entry[0] is da and entry[1] == origins:
                self._data.move_to_end(key)
                self.hits += 1
                return entry[2], entry[3]
            if count_miss:
                self.misses += 1
            return None

    def insert(self, key: Tuple, da: DeviceArrays, origins: Tuple,
               factor: np.ndarray, overheads) -> None:
        nbytes = self._entry_bytes(factor, overheads)
        with self._lock:
            old = self._data.pop(key, None)
            if old is not None:
                self._total_bytes -= old[4]
            self._data[key] = (da, origins, factor, overheads, nbytes)
            self._total_bytes += nbytes
            self.inserts += 1
            while self._data and (len(self._data) > self.capacity
                                  or self._total_bytes > self.max_bytes):
                _, evicted = self._data.popitem(last=False)
                self._total_bytes -= evicted[4]
                self.evictions += 1

    def stats(self) -> Dict[str, int]:
        """Counter snapshot under the lock (the ``/stats`` payload)."""
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "inserts": self.inserts, "evictions": self.evictions,
                    "entries": len(self._data),
                    "bytes": self._total_bytes,
                    "capacity": self.capacity,
                    "max_bytes": self.max_bytes}

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self._total_bytes = 0
            self.hits = self.misses = self.inserts = self.evictions = 0

    def export_state(self) -> List[Tuple]:
        """Pickle-safe snapshot of every entry (``serve/snapshot.py``).

        The stored ``DeviceArrays`` instance is identity-validated and
        cannot survive a process boundary, so each exported entry ships
        ``(key, origins, factor, overheads)`` only — the fleet names
        ride inside the key and :meth:`import_state` re-resolves them."""
        with self._lock:
            return [(key, e[1], e[2], e[3])
                    for key, e in self._data.items()]

    def import_state(self, entries) -> int:
        """Restore :meth:`export_state` entries into this cache.

        Each entry's fleet names are re-resolved through the memoized
        ``devices.arrays_for`` — yielding the exact instance the engine
        will present on lookup — so the instance-identity staleness
        guard keeps working after restore.  Entries naming devices no
        longer in the registry are skipped (the registry moved on; a
        stale factor must stay cold).  Returns the number restored."""
        restored = 0
        for key, origins, factor, overheads in entries:
            try:
                da = devices.arrays_for(key[1])
            except KeyError:
                continue
            self.insert(key, da, origins, factor, overheads)
            restored += 1
        return restored


#: the process-wide cross-stack wave-factor cache (see class docstring)
WAVE_FACTOR_CACHE = _WaveFactorCache()


def _factor_key(content: Tuple, da: DeviceArrays, exact: bool,
                model_overhead: bool) -> Tuple:
    """The one factor-cache key spelling shared by the single-trace and
    ragged paths, so a 1-trace stack and ``predict()`` on that trace hit
    the same entry."""
    return (content, tuple(da.names), exact, model_overhead)


def _roofline_core(flops, bytes_accessed, kernel_varying, peak_flops,
                   mem_bandwidth) -> np.ndarray:
    """Paleo-style roofline on broadcast-ready arrays.

    The one roofline expression behind both the grid and flat-cell
    spellings (the same drift guard ``_gamma_core`` provides for γ):
    every output element is produced by the same IEEE operation sequence
    regardless of input shapes, so the cell-masked sweep's bitwise
    parity with the full grid cannot be broken by editing one copy."""
    eff_c = np.where(kernel_varying, _EFF_COMPUTE[1], _EFF_COMPUTE[0])
    eff_m = np.where(kernel_varying, _EFF_MEMORY[1], _EFF_MEMORY[0])
    flops_t = (flops * (1.0 / eff_c)) / peak_flops
    mem_t = (bytes_accessed * (1.0 / eff_m)) / mem_bandwidth
    return np.maximum(flops_t, mem_t) * 1e3


def analytical_ms_vec(arrays: Union[TraceArrays, "RaggedTraceArrays"],
                      dests: DeviceArrays) -> np.ndarray:
    """Vectorized Paleo-style roofline estimate, shape (n_ops, n_dev)."""
    return _roofline_core(
        arrays.flops[:, None], arrays.bytes_accessed[:, None],
        np.asarray(arrays.kernel_varying)[:, None],
        dests.peak_flops[None, :], dests.mem_bandwidth[None, :])


def analytical_ms_flat(arrays, dests: DeviceArrays,
                       dest_idx: np.ndarray) -> np.ndarray:
    """Flat-cell spelling of :func:`analytical_ms_vec`, shape (M,).

    ``arrays`` rows are already gathered per cell; ``dest_idx[k]`` selects
    cell ``k``'s device.  The roofline formula is element-wise, so each
    cell equals the corresponding full-grid element bitwise — the
    cell-masked sweep relies on that to keep cached values history-free."""
    j = np.asarray(dest_idx, np.intp)
    return _roofline_core(arrays.flops, arrays.bytes_accessed,
                          arrays.kernel_varying, dests.peak_flops[j],
                          dests.mem_bandwidth[j])


def mlp_features_grid(arrays: Union[TraceArrays, "RaggedTraceArrays"],
                      idx: np.ndarray,
                      dests: DeviceArrays) -> np.ndarray:
    """MLP query features for ops ``idx`` x all devices, device-major rows.

    Row ``i * n_dev + j`` is op ``idx[i]`` queried against device ``j`` —
    the same log1p transform as :func:`repro.core.dataset.op_features`.

    This is the allocate-per-call reference spelling (kept as the
    ``feature_buffers=False`` compat path and as the oracle the buffered
    builder is tested against); the sweep hot path uses the preallocated
    split-transform builders below, which produce bitwise-identical rows
    without re-tiling or re-transforming the full grid per pass."""
    n_idx, n_dev = len(idx), dests.n
    op_part = np.repeat(arrays.op_features[idx], n_dev, axis=0)
    dev_part = np.tile(dests.feature_matrix, (n_idx, 1))
    raw = np.concatenate([op_part, dev_part], axis=1)
    return dataset_mod.transform_features(raw)


class _FeatureBufferPool:
    """Reusable float32 row buffers for the MLP feature grids.

    ``mlp_features_grid`` used to allocate (and log1p-transform) the full
    device-major grid on every sweep; this pool checks buffers out for
    the duration of one scoring call and back in afterwards, so repeated
    passes reuse storage instead of churning the allocator.  Checkout is
    exclusive (a buffer is never visible to two callers), which keeps
    concurrent planner/service threads safe without thread-local state —
    the service's coalescing leaders are short-lived threads, so
    thread-local buffers would never be reused."""

    _MAX_FREE = 8               # buffers kept per row width
    _MAX_BYTES = 16 << 20       # never retain one buffer above 16 MiB

    def __init__(self):
        self._free: Dict[int, List[np.ndarray]] = {}
        self._lock = threading.Lock()

    def acquire(self, n_rows: int, n_cols: int) -> np.ndarray:
        with self._lock:
            free = self._free.get(n_cols, [])
            for i, buf in enumerate(free):
                if buf.shape[0] >= n_rows:
                    return free.pop(i)
        cap = 1 << max(int(n_rows) - 1, 0).bit_length()
        return np.empty((max(cap, 1), n_cols), np.float32)

    def release(self, buf: np.ndarray) -> None:
        if buf.nbytes > self._MAX_BYTES:
            return      # one-off giant grids go back to the allocator
        with self._lock:
            free = self._free.setdefault(buf.shape[1], [])
            if len(free) < self._MAX_FREE:
                free.append(buf)


_FEATURE_BUFFERS = _FeatureBufferPool()


def _features_grid_into(buf: np.ndarray, op_feats_t: np.ndarray,
                        dev_feats_t: np.ndarray) -> np.ndarray:
    """Fill ``buf`` with the device-major feature grid, zero fresh allocs.

    ``op_feats_t``/``dev_feats_t`` are the *already transformed* op and
    device feature blocks: log1p is element-wise, so transforming each
    block once and broadcasting the results into the row grid yields the
    same bits as ``mlp_features_grid``'s transform-the-tiled-grid
    spelling, at 1/n_dev (op side) and 1/n_ops (device side) of the
    transform work."""
    n_idx, n_op_f = op_feats_t.shape
    n_dev, n_dev_f = dev_feats_t.shape
    rows = buf[:n_idx * n_dev]
    grid = rows.reshape(n_idx, n_dev, n_op_f + n_dev_f)
    grid[:, :, :n_op_f] = op_feats_t[:, None, :]
    grid[:, :, n_op_f:] = dev_feats_t[None, :, :]
    return rows


def _features_pairs_into(buf: np.ndarray, op_feats_t: np.ndarray,
                         dev_feats_t: np.ndarray, rows: np.ndarray,
                         cols: np.ndarray) -> np.ndarray:
    """Feature rows for an explicit (op, device) cell list (masked sweeps).

    Row ``k`` is op ``rows[k]`` x device ``cols[k]`` — identical bits to
    the corresponding ``mlp_features_grid`` row, but only the requested
    cells are materialized."""
    n_op_f = op_feats_t.shape[1]
    out = buf[:len(rows)]
    out[:, :n_op_f] = op_feats_t[rows]
    out[:, n_op_f:] = dev_feats_t[cols]
    return out


@dataclasses.dataclass
class FleetPrediction:
    """Per-(op, device) prediction grid for one trace against a fleet."""
    origin_device: str
    dests: List[str]
    op_ms: np.ndarray            # (n_ops, n_dev) single-execution times
    arrays: TraceArrays
    label: str = "iteration"

    @property
    def total_ms(self) -> np.ndarray:
        """Predicted iteration time per destination device, shape (n_dev,).

        Reduced with ``np.add.reduceat`` (strictly sequential row
        accumulation) rather than ``.sum(axis=0)`` (pairwise): the ragged
        sweep reduces its segments the same way, so a sweep row's totals
        equal this single-trace spelling BITWISE at any op count —
        pairwise association varies with segment size and would break
        that parity for traces over a few rows."""
        weighted = self.op_ms * self.arrays.multiplicity[:, None]
        if not weighted.shape[0]:
            return np.zeros(weighted.shape[1], weighted.dtype)
        return np.add.reduceat(weighted, [0], axis=0)[0]

    def time_for(self, dest: str) -> float:
        return float(self.total_ms[self.dests.index(dest)])

    def as_dict(self) -> Dict[str, float]:
        return dict(zip(self.dests, self.total_ms.tolist()))

    def breakdown(self, dest: str) -> Dict[str, float]:
        """Per-kind time breakdown on one destination (paper Fig. 4)."""
        j = self.dests.index(dest)
        weighted = self.op_ms[:, j] * self.arrays.multiplicity
        totals = np.bincount(self.arrays.kind_ids, weights=weighted,
                             minlength=len(self.arrays.kinds))
        return {k: float(t) for k, t in zip(self.arrays.kinds, totals)}


def _mlp_kind_rows(arrays, mlps: Dict):
    """Yield (kind, row indices) for each op kind with a trained MLP and
    at least one kernel-varying row — the one filter shared by the
    per-kind, fused, and masked scoring paths."""
    for kid, kind in enumerate(arrays.kinds):
        if kind not in mlps:
            continue
        idx = np.flatnonzero(arrays.kernel_varying
                             & (arrays.kind_ids == kid))
        if len(idx):
            yield kind, idx


def _mlp_scores_per_kind(arrays, da: DeviceArrays, mlps: Dict,
                         out: np.ndarray,
                         feature_buffers: bool = True) -> None:
    """Kernel-varying MLP rows: one jitted forward per kind, covering every
    destination device in the same batch.  Shared by the single-trace and
    ragged paths: the feature rows are identical, so pure-NumPy MLPs agree
    bitwise; real jitted forwards agree to float32 tolerance (the ragged
    batch pads to a different shape).

    ``feature_buffers=True`` routes the grid build through the pooled
    split-transform spelling (same bits, no per-pass reallocation);
    ``False`` keeps the allocate-per-call :func:`mlp_features_grid`
    reference path (benchmark baseline / kill switch)."""
    dev_t = (dataset_mod.transform_features(da.feature_matrix)
             if feature_buffers else None)
    n_feat = arrays.op_features.shape[1] + da.feature_matrix.shape[1]
    for kind, idx in _mlp_kind_rows(arrays, mlps):
        SCORER_DISPATCHES.bump("per_kind")
        if feature_buffers:
            op_t = dataset_mod.transform_features(arrays.op_features[idx])
            buf = _FEATURE_BUFFERS.acquire(len(idx) * da.n, n_feat)
            try:
                feats = _features_grid_into(buf, op_t, dev_t)
                preds = mlps[kind].predict_ms(feats)
            finally:
                _FEATURE_BUFFERS.release(buf)
        else:
            preds = mlps[kind].predict_ms(mlp_features_grid(arrays, idx,
                                                            da))
        out[idx] = preds.reshape(len(idx), da.n)


def predict_trace_batch(trace: TrackedTrace,
                        dests: Union[DeviceArrays, Sequence[str],
                                     Sequence[DeviceSpec]],
                        mlps: Optional[Dict] = None,
                        exact: bool = False,
                        model_overhead: bool = False,
                        feature_buffers: bool = True,
                        factor_cache: bool = True) -> FleetPrediction:
    """Predict one trace's per-op times on every destination at once.

    ``factor_cache=False`` bypasses :data:`WAVE_FACTOR_CACHE` and runs
    the unsplit ``scale_times_vec`` inline — bitwise the same numbers,
    kept as the benchmark baseline / kill switch (the cache is
    content-keyed, so even cache-averse callers would otherwise share
    warm factors across the process)."""
    origin = devices.get(trace.origin_device)
    da = devices.as_arrays(dests)
    arrays = trace.to_arrays()
    mlps = mlps or {}
    out = np.empty((arrays.n_ops, da.n), np.float64)

    # kernel-alike: wave scaling over the whole grid, with the
    # t-independent factor served from the cross-stack cache — repeat
    # predict()/predict_fleet() traffic (and 1-trace sweeps, which share
    # the key) skip the pow-heavy wave_factor_vec and pay only the
    # t * factor combine, which is bitwise the unsplit scale_times_vec
    alike = ~arrays.kernel_varying
    if alike.any():
        t_o = arrays.measured_ms[alike]
        if np.isnan(t_o).any():
            bad = int(np.flatnonzero(alike)[np.isnan(t_o).argmax()])
            raise ValueError(
                f"op {trace.ops[bad].name} has no origin measurement")
        sub = SimpleNamespace(intensity=arrays.intensity[alike],
                              bytes_accessed=arrays.bytes_accessed[alike])
        if not factor_cache:
            out[alike] = wave_scaling.scale_times_vec(
                t_o, sub, origin, da, exact=exact,
                model_overhead=model_overhead)
        else:
            key = _factor_key((trace.fingerprint(),), da, exact,
                              model_overhead)
            cached = WAVE_FACTOR_CACHE.get(key, da, (origin,))
            if cached is not None:
                factor, overheads = cached
            else:
                factor = wave_scaling.wave_factor_vec(sub, origin, da,
                                                      exact=exact)
                overheads = None
                if model_overhead:
                    oh_o, oh_d = wave_scaling.dispatch_overheads(origin,
                                                                 da)
                    # store the origin term per-op: the ragged paths
                    # index it by row, and broadcasting the scalar
                    # changes no bits
                    overheads = (np.full(len(t_o), oh_o, np.float64),
                                 oh_d)
                WAVE_FACTOR_CACHE.insert(key, da, (origin,), factor,
                                         overheads)
            out[alike] = wave_scaling.combine_wave_factor(t_o, factor,
                                                          overheads)

    # kernel-varying without an MLP: vectorized analytical fallback
    kind_has_mlp = np.asarray([k in mlps for k in arrays.kinds], bool)
    no_mlp = arrays.kernel_varying & ~kind_has_mlp[arrays.kind_ids]
    if no_mlp.any():
        out[no_mlp] = analytical_ms_vec(arrays, da)[no_mlp]

    _mlp_scores_per_kind(arrays, da, mlps, out,
                         feature_buffers=feature_buffers)

    return FleetPrediction(origin_device=trace.origin_device,
                           dests=list(da.names), op_ms=out, arrays=arrays,
                           label=trace.label)


# ---------------------------------------------------------------------------
# Multi-trace ragged grid: several traces x many devices in one pass.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RaggedTraceArrays:
    """Several traces stacked into one structure-of-arrays.

    Rows ``offsets[i]:offsets[i+1]`` belong to trace ``i``; ``kind_ids``
    index into the *unified* ``kinds`` list (union over all traces), so one
    per-kind MLP batch can span every trace at once.  Per-trace metadata
    (origin device, label, content fingerprint) rides along for the serve
    layer's per-trace result caching."""
    offsets: np.ndarray          # (n_traces + 1,) int64 segment boundaries
    trace_ids: np.ndarray        # (total_ops,) int32 row -> trace index
    origins: List[str]           # (n_traces,) origin device names
    labels: List[str]            # (n_traces,)
    fingerprints: List[str]      # (n_traces,) TrackedTrace.fingerprint()
    flops: np.ndarray            # (total_ops,)
    bytes_accessed: np.ndarray   # (total_ops,)
    intensity: np.ndarray        # (total_ops,)
    measured_ms: np.ndarray      # (total_ops,) NaN where unmeasured
    multiplicity: np.ndarray     # (total_ops,)
    kernel_varying: np.ndarray   # (total_ops,) bool
    kind_ids: np.ndarray         # (total_ops,) int32 into ``kinds``
    kinds: List[str]             # unified kinds, sorted
    op_features: np.ndarray      # (total_ops, 9) raw MLP op features
    _alike_origin: Optional[devices.OriginArrays] = dataclasses.field(
        default=None, repr=False, compare=False)
    _factor_token: Optional[Tuple] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def n_traces(self) -> int:
        return len(self.origins)

    @property
    def n_ops(self) -> int:
        return int(self.flops.shape[0])

    def segment(self, i: int) -> TraceArrays:
        """Trace ``i``'s rows as a plain :class:`TraceArrays` view."""
        s, e = int(self.offsets[i]), int(self.offsets[i + 1])
        return TraceArrays(
            flops=self.flops[s:e], bytes_accessed=self.bytes_accessed[s:e],
            intensity=self.intensity[s:e],
            measured_ms=self.measured_ms[s:e],
            multiplicity=self.multiplicity[s:e],
            kernel_varying=self.kernel_varying[s:e],
            kind_ids=self.kind_ids[s:e], kinds=self.kinds,
            op_features=self.op_features[s:e])

    def origin_arrays(self) -> devices.OriginArrays:
        """Per-op origin-device arrays for segment-aware wave scaling."""
        specs = [devices.get(o) for o in self.origins]
        return devices.repeat_origins(specs, np.diff(self.offsets))

    def alike_origin_arrays(self) -> devices.OriginArrays:
        """Origin arrays masked to the kernel-alike rows.

        Cached on the stack: the mask is a pure function of the (immutable)
        stacked arrays, and rebuilding it dominated the fixed per-sweep
        cost for small trace stacks."""
        if self._alike_origin is None:
            self._alike_origin = \
                self.origin_arrays().take(~self.kernel_varying)
        return self._alike_origin

    def factor_token(self) -> Tuple:
        """Content identity of this stack for the cross-stack factor
        cache: the tuple of trace fingerprints (a superset of what the
        factor depends on — alike-row arrays and per-trace origins).
        Memoized; a 1-trace stack's token equals ``(fingerprint,)``, the
        same token ``predict_trace_batch`` uses, so single-trace predict
        traffic and 1-trace sweeps share one cache entry."""
        if self._factor_token is None:
            self._factor_token = tuple(self.fingerprints)
        return self._factor_token

    def origin_specs(self) -> Tuple:
        """The per-trace origin ``DeviceSpec`` tuple as currently
        resolved — the factor cache validates entries against it by
        value, since the trace fingerprints name the origin device but
        do not hash its numbers (a monkeypatched/replaced registry entry
        must invalidate, not serve a stale factor)."""
        return tuple(devices.get(o) for o in self.origins)

    def alike_wave_factor(self, da: DeviceArrays, exact: bool,
                          model_overhead: bool):
        """Wave-scaling factor grid for the kernel-alike rows x ``da``:
        (factor (n_alike, n_dev), overheads-or-None).

        The factor is a pure function of this (immutable) stack and the
        destination fleet, so repeat sweeps skip the pow-heavy recompute
        and pay only the ``t * factor`` combine.  Since PR 5 the entry
        lives in the module-level :data:`WAVE_FACTOR_CACHE` keyed by
        content fingerprints — it survives this stack object and also
        serves ``predict_trace_batch`` and freshly-restacked sweeps over
        the same traces.  Stale-spec safety is the cache's validation of
        the destination ``DeviceArrays`` instance and the origin spec
        values (see its docstring)."""
        key = _factor_key(self.factor_token(), da, exact, model_overhead)
        origins = self.origin_specs()
        hit = WAVE_FACTOR_CACHE.get(key, da, origins)
        if hit is not None:
            return hit
        origin = self.alike_origin_arrays()
        alike = ~self.kernel_varying
        sub = SimpleNamespace(intensity=self.intensity[alike],
                              bytes_accessed=self.bytes_accessed[alike])
        factor = wave_scaling.wave_factor_vec(sub, origin, da, exact=exact)
        overheads = (wave_scaling.dispatch_overheads(origin, da)
                     if model_overhead else None)
        WAVE_FACTOR_CACHE.insert(key, da, origins, factor, overheads)
        return factor, overheads

    def peek_wave_factor(self, da: DeviceArrays, exact: bool,
                         model_overhead: bool):
        """The cached factor for ``da`` if warm, else None — masked
        sweeps must not pay a full-grid factor build for partial work
        (and a cold peek is not a counted miss, see the cache's
        ``peek``)."""
        return WAVE_FACTOR_CACHE.peek(
            _factor_key(self.factor_token(), da, exact, model_overhead),
            da, self.origin_specs())

    def extend(self, traces: Sequence[TrackedTrace]) -> "RaggedTraceArrays":
        """Append traces, reusing this stack's arrays for the shared prefix.

        Returns a NEW stack (stacks are immutable once built — the stack
        cache hands one instance to many sweeps).  Concatenating the
        ready prefix with just the new tail produces bit-identical arrays
        to restacking everything: segment data is copied verbatim and the
        unified kind vocabulary is the same sorted union either way."""
        return _concat_stacks(self, _build_stack(list(traces)))


def _concat_stacks(a: RaggedTraceArrays,
                   b: RaggedTraceArrays) -> RaggedTraceArrays:
    if a.kinds == b.kinds:
        kinds, a_ids, b_ids = list(a.kinds), a.kind_ids, b.kind_ids
    else:
        kinds = sorted(set(a.kinds) | set(b.kinds))
        kmap = {k: i for i, k in enumerate(kinds)}
        a_ids = np.asarray([kmap[k] for k in a.kinds],
                           np.int32)[a.kind_ids]
        b_ids = np.asarray([kmap[k] for k in b.kinds],
                           np.int32)[b.kind_ids]
    cat = lambda f: np.concatenate([getattr(a, f), getattr(b, f)])
    return RaggedTraceArrays(
        offsets=np.concatenate([a.offsets, a.offsets[-1] + b.offsets[1:]]),
        trace_ids=np.concatenate([a.trace_ids,
                                  b.trace_ids + np.int32(a.n_traces)]),
        origins=a.origins + b.origins, labels=a.labels + b.labels,
        fingerprints=a.fingerprints + b.fingerprints,
        flops=cat("flops"), bytes_accessed=cat("bytes_accessed"),
        intensity=cat("intensity"), measured_ms=cat("measured_ms"),
        multiplicity=cat("multiplicity"),
        kernel_varying=cat("kernel_varying"),
        kind_ids=np.concatenate([a_ids, b_ids]), kinds=kinds,
        op_features=cat("op_features"))


class _StackCache:
    """Fingerprint-keyed LRU of built :class:`RaggedTraceArrays`.

    Keys are ``((fingerprint, label), ...)`` tuples — the label rides
    along because it is the one piece of sweep output not covered by the
    fingerprint.  An exact hit skips stacking entirely (zero repack); a
    request extending a cached *prefix* reuses the ready prefix arrays
    and only stacks the new tail.  Bounded by entry count AND bytes
    (prefix-extended supersets are independent copies, so an entry-only
    LRU could pin many near-duplicates of a large trace set); the
    process-wide instance reads its bounds from
    ``REPRO_STACK_CACHE_ENTRIES`` / ``REPRO_STACK_CACHE_BYTES``
    (defaults 16 entries / 256 MiB).
    Thread-safe: the serving layer's coalescing leaders stack from
    short-lived threads."""

    def __init__(self, capacity: Optional[int] = None,
                 max_bytes: Optional[int] = None):
        self.capacity = (env_int("REPRO_STACK_CACHE_ENTRIES", 16)
                         if capacity is None else capacity)
        self.max_bytes = (env_int("REPRO_STACK_CACHE_BYTES", 256 << 20)
                          if max_bytes is None else max_bytes)
        self._data: "OrderedDict[Tuple, RaggedTraceArrays]" = OrderedDict()
        self._bytes: Dict[Tuple, int] = {}
        self._total_bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.extends = 0
        self.builds = 0

    @staticmethod
    def _nbytes(stack: RaggedTraceArrays) -> int:
        return sum(getattr(stack, f).nbytes
                   for f in ("offsets", "trace_ids", "flops",
                             "bytes_accessed", "intensity", "measured_ms",
                             "multiplicity", "kernel_varying", "kind_ids",
                             "op_features"))

    def stack(self, traces: List[TrackedTrace]) -> RaggedTraceArrays:
        key = tuple((t.fingerprint(), t.label) for t in traces)
        with self._lock:
            hit = self._data.get(key)
            if hit is not None:
                self._data.move_to_end(key)
                self.hits += 1
                return hit
            best: Optional[Tuple] = None
            for k in self._data:
                if len(k) < len(key) and key[:len(k)] == k \
                        and (best is None or len(k) > len(best)):
                    best = k
            base = self._data[best] if best is not None else None
        if base is not None:
            stack = base.extend(traces[len(best):])
        else:
            stack = _build_stack(traces)
        nbytes = self._nbytes(stack)
        with self._lock:
            self.extends += base is not None
            self.builds += base is None
            if key in self._data:       # racing fill: replace accounting
                self._total_bytes -= self._bytes.pop(key)
            self._data[key] = stack
            self._bytes[key] = nbytes
            self._total_bytes += nbytes
            self._data.move_to_end(key)
            while self._data and (len(self._data) > self.capacity
                                  or self._total_bytes > self.max_bytes):
                old_key, _ = self._data.popitem(last=False)
                self._total_bytes -= self._bytes.pop(old_key)
        return stack

    def stats(self) -> Dict[str, int]:
        """Counter snapshot under the lock (the ``/stats`` payload)."""
        with self._lock:
            return {"hits": self.hits, "extends": self.extends,
                    "builds": self.builds, "entries": len(self._data),
                    "bytes": self._total_bytes,
                    "capacity": self.capacity,
                    "max_bytes": self.max_bytes}

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self._bytes.clear()
            self._total_bytes = 0
            self.hits = self.extends = self.builds = 0

    def export_state(self) -> List[Tuple]:
        """Pickle-safe ``(key, stack)`` snapshot (``serve/snapshot.py``).

        :class:`RaggedTraceArrays` is numpy arrays + string lists all
        the way down (the private memo fields are plain dataclasses of
        the same), so entries pickle as-is."""
        with self._lock:
            return list(self._data.items())

    def import_state(self, entries) -> int:
        """Restore :meth:`export_state` entries (LRU/byte bounds apply).

        Imports do not count as builds — the restored warmth is the
        point, not engine work.  Returns the number restored."""
        restored = 0
        for key, stack in entries:
            nbytes = self._nbytes(stack)
            with self._lock:
                if key in self._data:
                    self._total_bytes -= self._bytes.pop(key)
                self._data[key] = stack
                self._bytes[key] = nbytes
                self._total_bytes += nbytes
                self._data.move_to_end(key)
                while self._data and (len(self._data) > self.capacity
                                      or self._total_bytes > self.max_bytes):
                    old_key, _ = self._data.popitem(last=False)
                    self._total_bytes -= self._bytes.pop(old_key)
            restored += 1
        return restored


#: the process-wide stack cache behind ``stack_traces(cache=True)``
STACK_CACHE = _StackCache()


def stack_traces(traces: Union["RaggedTraceArrays",
                               Sequence[TrackedTrace]],
                 cache: bool = True) -> RaggedTraceArrays:
    """Stack several :class:`TrackedTrace` into one ragged SoA.

    Idempotent (a ready :class:`RaggedTraceArrays` passes through), so hot
    callers can stack once and sweep many times.  With ``cache=True``
    (the default) the build is memoized in the process-wide
    :data:`STACK_CACHE` keyed by trace fingerprints: repeat sweeps over
    the same (or a superset of a cached) trace list skip the
    ``np.concatenate`` repack entirely.  ``cache=False`` forces a fresh
    build (benchmark baseline / kill switch)."""
    if isinstance(traces, RaggedTraceArrays):
        return traces
    traces = list(traces)
    if not traces:
        raise ValueError("stack_traces needs at least one trace")
    if cache:
        for t in traces:        # validate before keying the cache
            if t.to_arrays().n_ops == 0:
                raise ValueError(f"trace {t.label!r} has no ops")
        return STACK_CACHE.stack(traces)
    return _build_stack(traces)


def _build_stack(traces: List[TrackedTrace]) -> RaggedTraceArrays:
    if not traces:
        raise ValueError("stack_traces needs at least one trace")
    per = [t.to_arrays() for t in traces]
    for t, p in zip(traces, per):
        if p.n_ops == 0:
            raise ValueError(f"trace {t.label!r} has no ops")
    lengths = np.asarray([p.n_ops for p in per], np.int64)
    offsets = np.zeros(len(per) + 1, np.int64)
    np.cumsum(lengths, out=offsets[1:])
    cat = lambda field: np.concatenate([getattr(p, field) for p in per])
    if all(p.kinds == per[0].kinds for p in per[1:]):
        # fast path: one shared kind vocabulary (the common serving case —
        # traces of one model family), no per-trace id remap needed
        kinds = list(per[0].kinds)
        kind_ids = cat("kind_ids")
    else:
        kinds = sorted(set().union(*(p.kinds for p in per)))
        kmap = {k: i for i, k in enumerate(kinds)}
        kind_ids = np.concatenate([
            np.asarray([kmap[k] for k in p.kinds], np.int32)[p.kind_ids]
            for p in per])
    return RaggedTraceArrays(
        offsets=offsets,
        trace_ids=np.repeat(np.arange(len(per), dtype=np.int32), lengths),
        origins=[t.origin_device for t in traces],
        labels=[t.label for t in traces],
        fingerprints=[t.fingerprint() for t in traces],
        flops=cat("flops"), bytes_accessed=cat("bytes_accessed"),
        intensity=cat("intensity"), measured_ms=cat("measured_ms"),
        multiplicity=cat("multiplicity"),
        kernel_varying=cat("kernel_varying"),
        kind_ids=kind_ids, kinds=kinds, op_features=cat("op_features"))


@dataclasses.dataclass
class SweepPrediction:
    """The (n_traces x n_devices) what-if grid of one ragged sweep."""
    dests: List[str]
    op_ms: np.ndarray            # (total_ops, n_dev)
    arrays: RaggedTraceArrays
    _totals: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def n_traces(self) -> int:
        return self.arrays.n_traces

    @property
    def labels(self) -> List[str]:
        return self.arrays.labels

    @property
    def total_ms(self) -> np.ndarray:
        """Iteration time grid, shape (n_traces, n_dev).

        One ``np.add.reduceat`` over the segment offsets instead of a
        per-trace Python loop; ``FleetPrediction.total_ms`` uses the same
        strictly-sequential reduceat accumulation, so row i stays
        bit-identical to predicting trace i alone at any segment length.
        Cell-masked sweeps leave NaN in uncomputed cells, which the
        reduction propagates — readers must only consult computed cells.
        Memoized: cell-by-cell readers (``time_for``) must not re-reduce
        the grid per access."""
        if self._totals is None:
            weighted = self.op_ms * self.arrays.multiplicity[:, None]
            self._totals = np.add.reduceat(weighted,
                                           self.arrays.offsets[:-1], axis=0)
        return self._totals

    def row(self, i: int) -> FleetPrediction:
        """Trace ``i``'s slice as a full :class:`FleetPrediction`."""
        s, e = int(self.arrays.offsets[i]), int(self.arrays.offsets[i + 1])
        return FleetPrediction(origin_device=self.arrays.origins[i],
                               dests=list(self.dests),
                               op_ms=self.op_ms[s:e],
                               arrays=self.arrays.segment(i),
                               label=self.arrays.labels[i])

    def time_for(self, i: int, dest: str) -> float:
        return float(self.total_ms[i, self.dests.index(dest)])

    def as_dicts(self) -> List[Dict[str, float]]:
        # one C-level tolist() for the whole grid, not one per trace
        return [dict(zip(self.dests, row)) for row in self.total_ms.tolist()]


class FusedMLPScorer:
    """Packs all op-kind MLPs for the one-launch Pallas scorer.

    The per-kind jitted forwards pay one dispatch per kind per sweep; this
    scorer groups all kernel-varying feature rows by kind, pads each group
    to whole ``block_m`` row blocks, and evaluates everything in a single
    ``kernels.ops.fused_mlp_score`` call (compiled Pallas on TPU,
    interpret-mode or the jnp oracle on CPU).

    Requires every packed MLP to share one architecture — true for
    ``train_mlps`` output, which trains all four kinds with one config.
    """

    def __init__(self, mlps: Dict, block_m: int = 128, impl: str = "auto"):
        from repro.core import mlp as mlp_mod
        from repro.kernels import ops as kernel_ops
        import jax.numpy as jnp
        if not mlps:
            raise ValueError("FusedMLPScorer needs at least one MLP")
        self.kinds = sorted(mlps)
        arches = {(m.cfg.hidden_layers, m.cfg.hidden_size,
                   m.params[0][0].shape[0]) for m in mlps.values()}
        if len(arches) != 1:
            raise ValueError(
                f"fused scorer needs architecture-uniform MLPs, got "
                f"{sorted(arches)}")
        _, self.hidden, self.in_features = arches.pop()
        ws, bs = [], []
        for kind in self.kinds:
            w, b = kernel_ops.pack_mlp_params(
                mlps[kind].params, self.in_features, self.hidden)
            ws.append(w)
            bs.append(b)
        self.weights = jnp.stack(ws)          # (K, L, H, H)
        self.biases = jnp.stack(bs)           # (K, L, H)
        self.mlps = dict(mlps)                # normalization + output contract
        self.block_m = block_m
        self.impl = impl
        # the row-mapped path standardizes per row via these stacked
        # normalization constants (one vectorized expression, elementwise
        # identical to per-kind normalize()); MLPs with an overridden
        # normalize/ms_from_log keep the per-kind loops instead
        self._stock_contract = all(
            type(m).normalize is mlp_mod.TrainedMLP.normalize
            and type(m).ms_from_log is mlp_mod.TrainedMLP.ms_from_log
            for m in mlps.values())
        if self._stock_contract:
            self._feat_mean = np.stack(
                [np.asarray(mlps[k].feature_mean) for k in self.kinds])
            self._feat_std = np.stack(
                [np.asarray(mlps[k].feature_std) for k in self.kinds])

    def score_ms(self, feats_by_kind: Dict[str, np.ndarray]
                 ) -> Dict[str, np.ndarray]:
        """Raw feature rows per kind -> predicted ms per kind, one launch.

        The block count is padded to a jit bucket
        (:func:`repro.kernels.fused_mlp_score.bucket_blocks`) before the
        launch: coalesced service batches arrive at arbitrary sizes, and
        without bucketing every distinct size would recompile the jitted
        scorer.  Padding blocks carry zero rows through MLP 0 and are
        sliced off before un-logging."""
        from repro.kernels import ops as kernel_ops
        from repro.kernels.fused_mlp_score import bucket_blocks
        import jax.numpy as jnp
        if not any(f.shape[0] for f in feats_by_kind.values()):
            # bucket_blocks(0) == 0 by contract: never launch an empty
            # kernel — answer the degenerate query directly instead
            return {kind: self.mlps[kind].ms_from_log(
                        np.zeros(0, np.float32))
                    for kind in feats_by_kind}
        bm = self.block_m
        blocks, kind_of_block, counts = [], [], []
        for kind, feats in feats_by_kind.items():
            x = self.mlps[kind].normalize(feats)
            n = x.shape[0]
            nb = -(-n // bm)
            xp = np.zeros((nb * bm, self.hidden), np.float32)
            xp[:n, :x.shape[1]] = x
            blocks.append(xp)
            kind_of_block.extend([self.kinds.index(kind)] * nb)
            counts.append(n)
        pad_blocks = bucket_blocks(len(kind_of_block)) - len(kind_of_block)
        if pad_blocks:
            blocks.append(np.zeros((pad_blocks * bm, self.hidden),
                                   np.float32))
            kind_of_block.extend([0] * pad_blocks)
        SCORER_DISPATCHES.bump("fused")
        log_ms = np.asarray(kernel_ops.fused_mlp_score(
            jnp.asarray(np.concatenate(blocks)),
            jnp.asarray(np.asarray(kind_of_block, np.int32)),
            self.weights, self.biases, block_m=bm, impl=self.impl))
        out, offset = {}, 0
        for kind, n in zip(feats_by_kind, counts):
            out[kind] = self.mlps[kind].ms_from_log(
                log_ms[offset:offset + n])
            offset += (-(-n // bm)) * bm
        return out

    def _normalized_rows(self, feats: np.ndarray,
                         kind_ids: np.ndarray) -> np.ndarray:
        """Per-row standardized features, (m, n_raw_feat) float64.

        One vectorized expression over gathered per-kind constants for
        stock ``TrainedMLP``s — elementwise identical bits to
        ``normalize()`` on per-kind slices — and the per-kind loop for
        anything with an overridden contract."""
        if self._stock_contract:
            return ((np.atleast_2d(feats) - self._feat_mean[kind_ids])
                    / self._feat_std[kind_ids])
        out = np.empty(np.atleast_2d(feats).shape, np.float64)
        for ki, kind in enumerate(self.kinds):
            rows = np.flatnonzero(kind_ids == ki)
            if len(rows):
                out[rows] = self.mlps[kind].normalize(feats[rows])
        return out

    def _ms_from_log_rows(self, log_ms: np.ndarray,
                          kind_ids: np.ndarray) -> np.ndarray:
        """Per-row output contract: one vectorized un-log for stock
        MLPs (``ms_from_log`` is one shared static function), per kind
        otherwise."""
        if self._stock_contract:
            from repro.core.mlp import TrainedMLP
            return np.asarray(TrainedMLP.ms_from_log(log_ms), np.float64)
        out = np.empty(log_ms.shape[0], np.float64)
        for ki, kind in enumerate(self.kinds):
            rows = np.flatnonzero(kind_ids == ki)
            if len(rows):
                out[rows] = self.mlps[kind].ms_from_log(log_ms[rows])
        return out

    def score_rows_ms(self, feats: np.ndarray,
                      kind_ids: np.ndarray) -> np.ndarray:
        """Raw feature rows in ANY kind order -> predicted ms, one launch.

        ``kind_ids[i]`` indexes ``self.kinds`` for row ``i`` — callers
        need no per-kind grouping, so the cell-masked pair path costs
        exactly ONE scorer dispatch however many op kinds its cold cells
        mix.  Two lowerings behind the same contract:

        * Pallas/interpret: the row-mapped kernel
          (:func:`~repro.kernels.fused_mlp_score.fused_mlp_score_rows`)
          with scalar-prefetched kind maps — rows stay in caller order,
          padded to a ``bucket_blocks`` jit bucket (padding rides kind
          0, garbage by contract, sliced off);
        * jnp (the CPU backend): rows are regrouped by kind host-side
          into a (K, bucket_rows(max), H) stack and scored by ONE
          K-batched jitted gemm chain — on CPU there is no DMA schedule
          to preserve, and skipping the kernel's every-kind-per-row
          select work keeps the single dispatch cheaper than even one
          per-kind forward of the same rows.

        Normalization and the output contract stay per kind, shared
        with ``predict_ms``."""
        from repro.kernels import ops as kernel_ops
        from repro.kernels.fused_mlp_score import (bucket_blocks,
                                                  bucket_rows)
        import jax.numpy as jnp
        kind_ids = np.asarray(kind_ids, np.int32)
        m = feats.shape[0]
        if m == 0:
            return np.zeros(0, np.float64)
        xn = self._normalized_rows(feats, kind_ids)
        impl = kernel_ops._resolve(self.impl)
        SCORER_DISPATCHES.bump("fused")
        if impl == "jnp":
            rows_by_kind = [np.flatnonzero(kind_ids == ki)
                            for ki in range(len(self.kinds))]
            bpad = bucket_rows(max(len(r) for r in rows_by_kind))
            xs = np.zeros((len(self.kinds), bpad, self.hidden), np.float32)
            for ki, rows in enumerate(rows_by_kind):
                xs[ki, :len(rows), :xn.shape[1]] = xn[rows]
            log_grid = np.asarray(kernel_ops.fused_mlp_score_stacked(
                jnp.asarray(xs), self.weights, self.biases))
            log_ms = np.empty(m, np.float32)
            for ki, rows in enumerate(rows_by_kind):
                log_ms[rows] = log_grid[ki, :len(rows)]
        else:
            bm = self.block_m
            padded = bucket_blocks(-(-m // bm)) * bm
            xp = np.zeros((padded, self.hidden), np.float32)
            row_kinds = np.zeros(padded, np.int32)
            row_kinds[:m] = kind_ids
            xp[:m, :xn.shape[1]] = xn
            log_ms = np.asarray(kernel_ops.fused_mlp_score_rows(
                jnp.asarray(xp), jnp.asarray(row_kinds), self.weights,
                self.biases, block_m=bm, impl=impl))[:m]
        return self._ms_from_log_rows(log_ms, kind_ids)


def _resolve_scorer(scorer, mlps: Dict):
    """Map a ``predict_sweep`` scorer spelling to a usable instance.

    ``None``/"off" -> per-kind jitted forwards; "auto" -> fused Pallas
    only on a TPU backend (CPU keeps strict parity with
    ``predict_fleet``), silently falling back when the MLP set is not
    architecture-uniform; an impl name ("pallas" | "interpret" | "jnp")
    forces the fused path (and raises on non-uniform MLPs); a ready
    :class:`FusedMLPScorer` is used as-is.  The single policy shared by
    ``predict_sweep`` and ``HabitatPredictor`` (which only adds caching).
    """
    if scorer is None or scorer == "off" or not mlps:
        return None
    if isinstance(scorer, FusedMLPScorer):
        return scorer
    if scorer == "auto":
        import jax
        if jax.default_backend() != "tpu":
            return None
        try:
            return FusedMLPScorer(mlps, impl="pallas")
        except (ValueError, AttributeError):
            # mixed architectures, or duck-typed MLPs exposing only
            # predict_ms: best-effort falls back to per-kind forwards
            return None
    if scorer in ("pallas", "interpret", "jnp"):
        return FusedMLPScorer(mlps, impl=scorer)
    raise ValueError(f"unknown scorer spelling {scorer!r}")


def predict_sweep(traces: Union[RaggedTraceArrays, Sequence[TrackedTrace]],
                  dests: Union[DeviceArrays, Sequence[str],
                               Sequence[DeviceSpec]],
                  mlps: Optional[Dict] = None,
                  exact: bool = False,
                  model_overhead: bool = False,
                  scorer=None,
                  cell_mask: Optional[np.ndarray] = None,
                  stack_cache: bool = True,
                  feature_buffers: bool = True,
                  factor_cache: bool = True) -> SweepPrediction:
    """Predict every trace on every destination in one ragged pass.

    Row i of the result reproduces :func:`predict_trace_batch` on trace i
    alone.  Wave scaling broadcasts per-op origin arrays through the same
    IEEE expression and the analytical fallback is the same element-wise
    grid function, so those rows agree BITWISE.  Trained-MLP rows go
    through the same per-kind batched forwards (when no fused ``scorer``
    is active) but batch all traces' ops together, so their jitted
    float32 batches pad to different shapes than the per-trace spelling —
    equal to ~1e-6 relative, not bit-for-bit.

    ``cell_mask`` — bool (n_traces, n_dev), True = compute — enables
    partial-compute sweeps: only masked-in cells are evaluated (wave
    scaling and the analytical fallback via flat element-wise gathers,
    bitwise-equal to the full grid; MLP rows via pair-gathered feature
    rows, tolerance-equal like any re-batched MLP forward), and every
    masked-out cell is left NaN.  The serve layer uses this to fill only
    the cache-cold cells of a sweep.  ``stack_cache``/``feature_buffers``/
    ``factor_cache`` select the zero-repack stack cache, the pooled
    feature buffers, and the cross-stack wave-factor cache (defaults on;
    all off is the allocate-and-recompute-everything compat spelling —
    ``factor_cache=False`` matters for baselines because the factor
    cache is content-keyed and would otherwise stay warm across even a
    fresh restack).
    """
    ragged = stack_traces(traces, cache=stack_cache)
    da = devices.as_arrays(dests)
    mlps = mlps or {}
    if cell_mask is not None:
        cell_mask = np.asarray(cell_mask, bool)
        if cell_mask.shape != (ragged.n_traces, da.n):
            raise ValueError(
                f"cell_mask shape {cell_mask.shape} != "
                f"(n_traces, n_dev) = {(ragged.n_traces, da.n)}")
        if cell_mask.all():
            cell_mask = None    # the full grid is the fast spelling
    if cell_mask is not None:
        return _predict_sweep_masked(ragged, da, mlps, exact,
                                     model_overhead, scorer, cell_mask,
                                     feature_buffers=feature_buffers,
                                     factor_cache=factor_cache)
    out = np.empty((ragged.n_ops, da.n), np.float64)

    # kernel-alike: segment-aware wave scaling over the whole ragged
    # grid, with the t-independent factor served from the cross-stack
    # cache — a repeat sweep pays only the t * factor combine
    alike = ~ragged.kernel_varying
    if alike.any():
        t_o = ragged.measured_ms[alike]
        if np.isnan(t_o).any():
            _raise_unmeasured(ragged, np.flatnonzero(alike), t_o)
        if factor_cache:
            factor, overheads = ragged.alike_wave_factor(da, exact,
                                                         model_overhead)
            out[alike] = wave_scaling.combine_wave_factor(t_o, factor,
                                                          overheads)
        else:
            sub = SimpleNamespace(
                intensity=ragged.intensity[alike],
                bytes_accessed=ragged.bytes_accessed[alike])
            out[alike] = wave_scaling.scale_times_vec(
                t_o, sub, ragged.alike_origin_arrays(), da, exact=exact,
                model_overhead=model_overhead)

    # kernel-varying without an MLP: vectorized analytical fallback,
    # computed on the masked rows only (the formula is element-wise, so
    # this matches predict_trace_batch's full-grid-then-mask bitwise)
    no_mlp = _no_mlp_rows(ragged, mlps)
    if no_mlp.any():
        sub = SimpleNamespace(
            kernel_varying=ragged.kernel_varying[no_mlp],
            flops=ragged.flops[no_mlp],
            bytes_accessed=ragged.bytes_accessed[no_mlp])
        out[no_mlp] = analytical_ms_vec(sub, da)

    # kernel-varying with an MLP: fused one-launch scorer when available,
    # otherwise the same per-kind batched forwards as predict_trace_batch
    fused = _resolve_scorer(scorer, mlps)
    if fused is not None:
        feats_by_kind: Dict[str, np.ndarray] = {}
        idx_by_kind: Dict[str, np.ndarray] = {}
        bufs: List[np.ndarray] = []
        dev_t = (dataset_mod.transform_features(da.feature_matrix)
                 if feature_buffers else None)
        n_feat = ragged.op_features.shape[1] + da.feature_matrix.shape[1]
        try:
            for kind, idx in _mlp_kind_rows(ragged, mlps):
                idx_by_kind[kind] = idx
                if feature_buffers:
                    op_t = dataset_mod.transform_features(
                        ragged.op_features[idx])
                    buf = _FEATURE_BUFFERS.acquire(len(idx) * da.n, n_feat)
                    bufs.append(buf)
                    feats_by_kind[kind] = _features_grid_into(buf, op_t,
                                                              dev_t)
                else:
                    feats_by_kind[kind] = mlp_features_grid(ragged, idx, da)
            if feats_by_kind:
                scored = fused.score_ms(feats_by_kind)
                for kind, idx in idx_by_kind.items():
                    out[idx] = scored[kind].reshape(len(idx), da.n)
        finally:
            for buf in bufs:
                _FEATURE_BUFFERS.release(buf)
    else:
        _mlp_scores_per_kind(ragged, da, mlps, out,
                             feature_buffers=feature_buffers)

    return SweepPrediction(dests=list(da.names), op_ms=out, arrays=ragged)


def _no_mlp_rows(ragged: RaggedTraceArrays, mlps: Dict) -> np.ndarray:
    kind_has_mlp = np.asarray([k in mlps for k in ragged.kinds], bool)
    return ragged.kernel_varying & ~kind_has_mlp[ragged.kind_ids]


def _raise_unmeasured(ragged: RaggedTraceArrays, rows: np.ndarray,
                      t_o: np.ndarray) -> None:
    bad = int(rows[np.isnan(t_o).argmax()])
    tid = int(ragged.trace_ids[bad])
    raise ValueError(
        f"trace {ragged.labels[tid]!r} op row "
        f"{bad - int(ragged.offsets[tid])} has no origin measurement")


#: mask-row pattern count up to which the masked sweep computes broadcast
#: subgrids per pattern group instead of per-cell gathers.  Production
#: warm structure clusters into a handful of patterns (clients warm a few
#: distinct fleets), where subgrids skip all gather/scatter overhead; a
#: fully random mask degenerates to one pattern per trace, where the flat
#: per-cell path wins.
_PATTERN_GROUP_LIMIT = 8


def _predict_sweep_masked(ragged: RaggedTraceArrays, da: DeviceArrays,
                          mlps: Dict, exact: bool, model_overhead: bool,
                          scorer, cell_mask: np.ndarray,
                          feature_buffers: bool = True,
                          factor_cache: bool = True) -> SweepPrediction:
    """Partial-compute sweep: evaluate only the masked-in cells.

    Every computed cell reproduces the full-grid value — bitwise on the
    wave-scaling/analytical paths (both the pattern-grouped subgrids and
    the flat per-cell gathers run the identical element-wise
    expressions), to MLP-forward tolerance on trained-MLP cells (pair
    batches pad differently, same caveat as any re-batched forward).
    Masked-out cells stay NaN; callers (the planner's cell-level cache
    fill) must only read computed cells."""
    out = np.full((ragged.n_ops, da.n), np.nan)
    op_mask = cell_mask[ragged.trace_ids]            # (n_ops, n_dev)
    patterns, inverse = np.unique(cell_mask, axis=0, return_inverse=True)
    inverse = np.asarray(inverse).reshape(-1)   # numpy 2.0 axis quirk
    grouped = len(patterns) <= _PATTERN_GROUP_LIMIT
    ao = ragged.alike_origin_arrays()
    alike_ops = ~ragged.kernel_varying
    no_mlp_ops = _no_mlp_rows(ragged, mlps)

    cached = (ragged.peek_wave_factor(da, exact, model_overhead)
              if factor_cache else None)
    if grouped:
        # position of each global op row inside the alike subset (the
        # origin arrays are stored alike-subset-major)
        alike_index = np.cumsum(alike_ops) - 1
        for p, pattern in enumerate(patterns):
            cols = np.flatnonzero(pattern)
            if not len(cols):
                continue
            in_group = (inverse == p)[ragged.trace_ids]
            da_sub = da.take(cols)
            rows = np.flatnonzero(in_group & alike_ops)
            if len(rows):
                t_o = ragged.measured_ms[rows]
                if np.isnan(t_o).any():
                    _raise_unmeasured(ragged, rows, t_o)
                pos = alike_index[rows]
                if cached is not None:
                    # warm factor: slice the cached grid (same elements,
                    # so the combine stays bitwise) instead of re-deriving
                    factor, overheads = cached
                    f_sub = factor[np.ix_(pos, cols)]
                    oh = (None if overheads is None else
                          (overheads[0][pos], overheads[1][cols]))
                    out[np.ix_(rows, cols)] = \
                        wave_scaling.combine_wave_factor(t_o, f_sub, oh)
                else:
                    sub = SimpleNamespace(
                        intensity=ragged.intensity[rows],
                        bytes_accessed=ragged.bytes_accessed[rows])
                    origin_sub = devices.OriginArrays(
                        kinds=([ao.kinds[i] for i in pos]
                               if model_overhead else []),
                        mem_bandwidth=ao.mem_bandwidth[pos],
                        clock_hz=ao.clock_hz[pos],
                        wave_size=ao.wave_size[pos])
                    out[np.ix_(rows, cols)] = wave_scaling.scale_times_vec(
                        t_o, sub, origin_sub, da_sub, exact=exact,
                        model_overhead=model_overhead)
            rows = np.flatnonzero(in_group & no_mlp_ops)
            if len(rows):
                sub = SimpleNamespace(
                    kernel_varying=ragged.kernel_varying[rows],
                    flops=ragged.flops[rows],
                    bytes_accessed=ragged.bytes_accessed[rows])
                out[np.ix_(rows, cols)] = analytical_ms_vec(sub, da_sub)
    else:
        # kernel-alike cells: flat element-wise wave scaling
        alike_rows = np.flatnonzero(alike_ops)
        if len(alike_rows):
            r, c = np.nonzero(op_mask[alike_rows])
            if len(r):
                rows = alike_rows[r]
                t_cells = ragged.measured_ms[rows]
                if np.isnan(t_cells).any():
                    _raise_unmeasured(ragged, rows, t_cells)
                if cached is not None:
                    factor, overheads = cached
                    f_cells = factor[r, c]
                    if overheads is None:
                        out[rows, c] = t_cells * f_cells
                    else:
                        oh_o, oh_d = overheads
                        out[rows, c] = (np.maximum(t_cells - oh_o[r], 0.0)
                                        * f_cells + oh_d[c])
                else:
                    sub = SimpleNamespace(
                        intensity=ragged.intensity[rows],
                        bytes_accessed=ragged.bytes_accessed[rows])
                    # gather origin fields directly: OriginArrays.take
                    # would materialize a per-cell Python list of kind
                    # strings, which only the overhead model reads
                    origin_cells = devices.OriginArrays(
                        kinds=([ao.kinds[i] for i in r]
                               if model_overhead else []),
                        mem_bandwidth=ao.mem_bandwidth[r],
                        clock_hz=ao.clock_hz[r], wave_size=ao.wave_size[r])
                    out[rows, c] = wave_scaling.scale_times_flat(
                        t_cells, sub, origin_cells, da, c, exact=exact,
                        model_overhead=model_overhead)

        # kernel-varying cells without an MLP: flat analytical fallback
        no_mlp_rows = np.flatnonzero(no_mlp_ops)
        if len(no_mlp_rows):
            r, c = np.nonzero(op_mask[no_mlp_rows])
            if len(r):
                rows = no_mlp_rows[r]
                sub = SimpleNamespace(
                    kernel_varying=ragged.kernel_varying[rows],
                    flops=ragged.flops[rows],
                    bytes_accessed=ragged.bytes_accessed[rows])
                out[rows, c] = analytical_ms_flat(sub, da, c)

    # kernel-varying cells with an MLP: pair-gathered feature rows.
    # With a fused scorer active, every kind's cold pairs are scored by
    # ONE row-mapped launch (each row carries its own kind id) — no
    # per-kind grouping, no per-kind block padding, exactly 1 scorer
    # dispatch for any kind mix.  Without one (the CPU "auto" default),
    # the PR 4 per-kind forwards run — kept as the parity baseline and
    # the bench_dispatch comparison point.
    fused = _resolve_scorer(scorer, mlps)
    dev_t = dataset_mod.transform_features(da.feature_matrix)
    n_feat = ragged.op_features.shape[1] + da.feature_matrix.shape[1]
    pairs: List[Tuple[str, np.ndarray, np.ndarray, np.ndarray]] = []
    for kind, idx in _mlp_kind_rows(ragged, mlps):
        r, c = np.nonzero(op_mask[idx])
        if len(r):
            pairs.append((kind, idx, r, c))

    def pair_features(buf, idx, r, c):
        # transform only rows that actually appear in cold pairs — work
        # stays proportional to cold cells, not to the kind's full op
        # count (log1p per row is identical either way)
        used, r_used = np.unique(r, return_inverse=True)
        op_t = dataset_mod.transform_features(ragged.op_features[idx[used]])
        return _features_pairs_into(buf, op_t, dev_t, r_used, c)

    if pairs and fused is not None:
        total = sum(len(r) for _, _, r, _ in pairs)
        buf = (_FEATURE_BUFFERS.acquire(total, n_feat) if feature_buffers
               else np.empty((total, n_feat), np.float32))
        try:
            kind_rows = np.empty(total, np.int32)
            offset = 0
            for kind, idx, r, c in pairs:
                pair_features(buf[offset:offset + len(r)], idx, r, c)
                kind_rows[offset:offset + len(r)] = fused.kinds.index(kind)
                offset += len(r)
            scored = fused.score_rows_ms(buf[:total], kind_rows)
        finally:
            if feature_buffers:
                _FEATURE_BUFFERS.release(buf)
        offset = 0
        for kind, idx, r, c in pairs:
            out[idx[r], c] = scored[offset:offset + len(r)]
            offset += len(r)
    elif pairs:
        for kind, idx, r, c in pairs:
            buf = (_FEATURE_BUFFERS.acquire(len(r), n_feat)
                   if feature_buffers
                   else np.empty((len(r), n_feat), np.float32))
            try:
                feats = pair_features(buf, idx, r, c)
                SCORER_DISPATCHES.bump("per_kind")
                out[idx[r], c] = mlps[kind].predict_ms(feats)
            finally:
                if feature_buffers:
                    _FEATURE_BUFFERS.release(buf)

    return SweepPrediction(dests=list(da.names), op_ms=out, arrays=ragged)
