"""Vectorized fleet-prediction engine: traces against many devices.

The serving question Habitat answers is "from the one device you own, rank
every device you could buy" (Sec. 5.3) — at production scale that is one
trace predicted against *dozens* of destinations per request.  The per-op
Python loop in the original ``HabitatPredictor.predict_trace`` pays the
interpreter cost once per (op, device) pair; this module pays it once per
trace — and, for fleet-wide what-if sweeps (many batch sizes / model
variants x many devices), once per *stack* of traces.

The pipeline is fully array-shaped:

  * kernel-alike ops   -> ``wave_scaling.scale_times_vec`` fills the whole
                          (n_ops x n_devices) grid in one NumPy expression,
  * kernel-varying ops -> one batched MLP inference per kind covering *all*
                          destinations at once (features tiled device-major),
                          falling back to a vectorized Paleo-style roofline
                          when no MLP is available for a kind.

``FleetPrediction`` keeps the per-(op, device) grid so per-kind breakdowns
and per-device totals are both O(1) array reductions afterwards.

Multi-trace layer: :func:`stack_traces` concatenates several traces into a
:class:`RaggedTraceArrays` (one structure-of-arrays with segment offsets),
:func:`predict_sweep` fills the whole (total_ops x n_devices) grid in one
pass — segment-aware wave scaling handles per-trace origins, and when all
four op-kind MLPs share an architecture the kernel-varying rows can be
scored by ONE fused Pallas launch (:class:`FusedMLPScorer`) instead of
four jitted per-kind forwards.  Row i of the resulting
:class:`SweepPrediction` equals ``predict_trace_batch`` on trace i alone:
bitwise on the wave-scaling and analytical paths, and to float32-forward
tolerance (~1e-6) on trained-MLP rows, whose jitted batches pad to
different shapes in the two spellings.
"""

from __future__ import annotations

import dataclasses
from types import SimpleNamespace
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core import dataset as dataset_mod
from repro.core import devices, wave_scaling
from repro.core.devices import DeviceArrays, DeviceSpec
from repro.core.trace import TraceArrays, TrackedTrace

#: Paleo-fallback efficiencies, matching ``predictor._analytical_ms``.
_EFF_COMPUTE = (0.50, 0.70)   # (kernel-alike, kernel-varying)
_EFF_MEMORY = (0.82, 0.75)


def analytical_ms_vec(arrays: Union[TraceArrays, "RaggedTraceArrays"],
                      dests: DeviceArrays) -> np.ndarray:
    """Vectorized Paleo-style roofline estimate, shape (n_ops, n_dev)."""
    eff_c = np.where(arrays.kernel_varying, _EFF_COMPUTE[1], _EFF_COMPUTE[0])
    eff_m = np.where(arrays.kernel_varying, _EFF_MEMORY[1], _EFF_MEMORY[0])
    flops_t = (arrays.flops * (1.0 / eff_c))[:, None] \
        / dests.peak_flops[None, :]
    mem_t = (arrays.bytes_accessed * (1.0 / eff_m))[:, None] \
        / dests.mem_bandwidth[None, :]
    return np.maximum(flops_t, mem_t) * 1e3


def mlp_features_grid(arrays: Union[TraceArrays, "RaggedTraceArrays"],
                      idx: np.ndarray,
                      dests: DeviceArrays) -> np.ndarray:
    """MLP query features for ops ``idx`` x all devices, device-major rows.

    Row ``i * n_dev + j`` is op ``idx[i]`` queried against device ``j`` —
    the same log1p transform as :func:`repro.core.dataset.op_features`."""
    n_idx, n_dev = len(idx), dests.n
    op_part = np.repeat(arrays.op_features[idx], n_dev, axis=0)
    dev_part = np.tile(dests.feature_matrix, (n_idx, 1))
    raw = np.concatenate([op_part, dev_part], axis=1)
    return dataset_mod.transform_features(raw)


@dataclasses.dataclass
class FleetPrediction:
    """Per-(op, device) prediction grid for one trace against a fleet."""
    origin_device: str
    dests: List[str]
    op_ms: np.ndarray            # (n_ops, n_dev) single-execution times
    arrays: TraceArrays
    label: str = "iteration"

    @property
    def total_ms(self) -> np.ndarray:
        """Predicted iteration time per destination device, shape (n_dev,)."""
        return (self.op_ms * self.arrays.multiplicity[:, None]).sum(axis=0)

    def time_for(self, dest: str) -> float:
        return float(self.total_ms[self.dests.index(dest)])

    def as_dict(self) -> Dict[str, float]:
        return dict(zip(self.dests, self.total_ms.tolist()))

    def breakdown(self, dest: str) -> Dict[str, float]:
        """Per-kind time breakdown on one destination (paper Fig. 4)."""
        j = self.dests.index(dest)
        weighted = self.op_ms[:, j] * self.arrays.multiplicity
        totals = np.bincount(self.arrays.kind_ids, weights=weighted,
                             minlength=len(self.arrays.kinds))
        return {k: float(t) for k, t in zip(self.arrays.kinds, totals)}


def _mlp_scores_per_kind(arrays, da: DeviceArrays, mlps: Dict,
                         out: np.ndarray) -> None:
    """Kernel-varying MLP rows: one jitted forward per kind, covering every
    destination device in the same batch.  Shared by the single-trace and
    ragged paths: the feature rows are identical, so pure-NumPy MLPs agree
    bitwise; real jitted forwards agree to float32 tolerance (the ragged
    batch pads to a different shape)."""
    for kid, kind in enumerate(arrays.kinds):
        if kind not in mlps:
            continue
        idx = np.flatnonzero(arrays.kernel_varying
                             & (arrays.kind_ids == kid))
        if not len(idx):
            continue
        feats = mlp_features_grid(arrays, idx, da)
        preds = mlps[kind].predict_ms(feats).reshape(len(idx), da.n)
        out[idx] = preds


def predict_trace_batch(trace: TrackedTrace,
                        dests: Union[DeviceArrays, Sequence[str],
                                     Sequence[DeviceSpec]],
                        mlps: Optional[Dict] = None,
                        exact: bool = False,
                        model_overhead: bool = False) -> FleetPrediction:
    """Predict one trace's per-op times on every destination at once."""
    origin = devices.get(trace.origin_device)
    da = devices.as_arrays(dests)
    arrays = trace.to_arrays()
    mlps = mlps or {}
    out = np.empty((arrays.n_ops, da.n), np.float64)

    # kernel-alike: wave scaling over the whole grid
    alike = ~arrays.kernel_varying
    if alike.any():
        t_o = arrays.measured_ms[alike]
        if np.isnan(t_o).any():
            bad = int(np.flatnonzero(alike)[np.isnan(t_o).argmax()])
            raise ValueError(
                f"op {trace.ops[bad].name} has no origin measurement")
        sub = SimpleNamespace(intensity=arrays.intensity[alike],
                              bytes_accessed=arrays.bytes_accessed[alike])
        out[alike] = wave_scaling.scale_times_vec(
            t_o, sub, origin, da, exact=exact,
            model_overhead=model_overhead)

    # kernel-varying without an MLP: vectorized analytical fallback
    kind_has_mlp = np.asarray([k in mlps for k in arrays.kinds], bool)
    no_mlp = arrays.kernel_varying & ~kind_has_mlp[arrays.kind_ids]
    if no_mlp.any():
        out[no_mlp] = analytical_ms_vec(arrays, da)[no_mlp]

    _mlp_scores_per_kind(arrays, da, mlps, out)

    return FleetPrediction(origin_device=trace.origin_device,
                           dests=list(da.names), op_ms=out, arrays=arrays,
                           label=trace.label)


# ---------------------------------------------------------------------------
# Multi-trace ragged grid: several traces x many devices in one pass.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RaggedTraceArrays:
    """Several traces stacked into one structure-of-arrays.

    Rows ``offsets[i]:offsets[i+1]`` belong to trace ``i``; ``kind_ids``
    index into the *unified* ``kinds`` list (union over all traces), so one
    per-kind MLP batch can span every trace at once.  Per-trace metadata
    (origin device, label, content fingerprint) rides along for the serve
    layer's per-trace result caching."""
    offsets: np.ndarray          # (n_traces + 1,) int64 segment boundaries
    trace_ids: np.ndarray        # (total_ops,) int32 row -> trace index
    origins: List[str]           # (n_traces,) origin device names
    labels: List[str]            # (n_traces,)
    fingerprints: List[str]      # (n_traces,) TrackedTrace.fingerprint()
    flops: np.ndarray            # (total_ops,)
    bytes_accessed: np.ndarray   # (total_ops,)
    intensity: np.ndarray        # (total_ops,)
    measured_ms: np.ndarray      # (total_ops,) NaN where unmeasured
    multiplicity: np.ndarray     # (total_ops,)
    kernel_varying: np.ndarray   # (total_ops,) bool
    kind_ids: np.ndarray         # (total_ops,) int32 into ``kinds``
    kinds: List[str]             # unified kinds, sorted
    op_features: np.ndarray      # (total_ops, 9) raw MLP op features
    _alike_origin: Optional[devices.OriginArrays] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def n_traces(self) -> int:
        return len(self.origins)

    @property
    def n_ops(self) -> int:
        return int(self.flops.shape[0])

    def segment(self, i: int) -> TraceArrays:
        """Trace ``i``'s rows as a plain :class:`TraceArrays` view."""
        s, e = int(self.offsets[i]), int(self.offsets[i + 1])
        return TraceArrays(
            flops=self.flops[s:e], bytes_accessed=self.bytes_accessed[s:e],
            intensity=self.intensity[s:e],
            measured_ms=self.measured_ms[s:e],
            multiplicity=self.multiplicity[s:e],
            kernel_varying=self.kernel_varying[s:e],
            kind_ids=self.kind_ids[s:e], kinds=self.kinds,
            op_features=self.op_features[s:e])

    def origin_arrays(self) -> devices.OriginArrays:
        """Per-op origin-device arrays for segment-aware wave scaling."""
        specs = [devices.get(o) for o in self.origins]
        return devices.repeat_origins(specs, np.diff(self.offsets))

    def alike_origin_arrays(self) -> devices.OriginArrays:
        """Origin arrays masked to the kernel-alike rows.

        Cached on the stack: the mask is a pure function of the (immutable)
        stacked arrays, and rebuilding it dominated the fixed per-sweep
        cost for small trace stacks."""
        if self._alike_origin is None:
            self._alike_origin = \
                self.origin_arrays().take(~self.kernel_varying)
        return self._alike_origin


def stack_traces(traces: Union["RaggedTraceArrays",
                               Sequence[TrackedTrace]]
                 ) -> RaggedTraceArrays:
    """Stack several :class:`TrackedTrace` into one ragged SoA.

    Idempotent (a ready :class:`RaggedTraceArrays` passes through), so hot
    callers can stack once and sweep many times."""
    if isinstance(traces, RaggedTraceArrays):
        return traces
    traces = list(traces)
    if not traces:
        raise ValueError("stack_traces needs at least one trace")
    per = [t.to_arrays() for t in traces]
    for t, p in zip(traces, per):
        if p.n_ops == 0:
            raise ValueError(f"trace {t.label!r} has no ops")
    lengths = np.asarray([p.n_ops for p in per], np.int64)
    offsets = np.zeros(len(per) + 1, np.int64)
    np.cumsum(lengths, out=offsets[1:])
    cat = lambda field: np.concatenate([getattr(p, field) for p in per])
    if all(p.kinds == per[0].kinds for p in per[1:]):
        # fast path: one shared kind vocabulary (the common serving case —
        # traces of one model family), no per-trace id remap needed
        kinds = list(per[0].kinds)
        kind_ids = cat("kind_ids")
    else:
        kinds = sorted(set().union(*(p.kinds for p in per)))
        kmap = {k: i for i, k in enumerate(kinds)}
        kind_ids = np.concatenate([
            np.asarray([kmap[k] for k in p.kinds], np.int32)[p.kind_ids]
            for p in per])
    return RaggedTraceArrays(
        offsets=offsets,
        trace_ids=np.repeat(np.arange(len(per), dtype=np.int32), lengths),
        origins=[t.origin_device for t in traces],
        labels=[t.label for t in traces],
        fingerprints=[t.fingerprint() for t in traces],
        flops=cat("flops"), bytes_accessed=cat("bytes_accessed"),
        intensity=cat("intensity"), measured_ms=cat("measured_ms"),
        multiplicity=cat("multiplicity"),
        kernel_varying=cat("kernel_varying"),
        kind_ids=kind_ids, kinds=kinds, op_features=cat("op_features"))


@dataclasses.dataclass
class SweepPrediction:
    """The (n_traces x n_devices) what-if grid of one ragged sweep."""
    dests: List[str]
    op_ms: np.ndarray            # (total_ops, n_dev)
    arrays: RaggedTraceArrays
    _totals: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def n_traces(self) -> int:
        return self.arrays.n_traces

    @property
    def labels(self) -> List[str]:
        return self.arrays.labels

    @property
    def total_ms(self) -> np.ndarray:
        """Iteration time grid, shape (n_traces, n_dev).

        Summed per segment with the same ``.sum(axis=0)`` reduction the
        single-trace ``FleetPrediction.total_ms`` uses, so row i is
        bit-identical to predicting trace i alone (``np.add.reduceat``
        would associate differently).  Memoized: cell-by-cell readers
        (``time_for``) must not re-reduce the grid per access."""
        if self._totals is None:
            off = self.arrays.offsets
            weighted = self.op_ms * self.arrays.multiplicity[:, None]
            self._totals = np.stack(
                [weighted[off[i]:off[i + 1]].sum(axis=0)
                 for i in range(self.n_traces)])
        return self._totals

    def row(self, i: int) -> FleetPrediction:
        """Trace ``i``'s slice as a full :class:`FleetPrediction`."""
        s, e = int(self.arrays.offsets[i]), int(self.arrays.offsets[i + 1])
        return FleetPrediction(origin_device=self.arrays.origins[i],
                               dests=list(self.dests),
                               op_ms=self.op_ms[s:e],
                               arrays=self.arrays.segment(i),
                               label=self.arrays.labels[i])

    def time_for(self, i: int, dest: str) -> float:
        return float(self.total_ms[i, self.dests.index(dest)])

    def as_dicts(self) -> List[Dict[str, float]]:
        totals = self.total_ms
        return [dict(zip(self.dests, totals[i].tolist()))
                for i in range(self.n_traces)]


class FusedMLPScorer:
    """Packs all op-kind MLPs for the one-launch Pallas scorer.

    The per-kind jitted forwards pay one dispatch per kind per sweep; this
    scorer groups all kernel-varying feature rows by kind, pads each group
    to whole ``block_m`` row blocks, and evaluates everything in a single
    ``kernels.ops.fused_mlp_score`` call (compiled Pallas on TPU,
    interpret-mode or the jnp oracle on CPU).

    Requires every packed MLP to share one architecture — true for
    ``train_mlps`` output, which trains all four kinds with one config.
    """

    def __init__(self, mlps: Dict, block_m: int = 128, impl: str = "auto"):
        from repro.kernels import ops as kernel_ops
        import jax.numpy as jnp
        if not mlps:
            raise ValueError("FusedMLPScorer needs at least one MLP")
        self.kinds = sorted(mlps)
        arches = {(m.cfg.hidden_layers, m.cfg.hidden_size,
                   m.params[0][0].shape[0]) for m in mlps.values()}
        if len(arches) != 1:
            raise ValueError(
                f"fused scorer needs architecture-uniform MLPs, got "
                f"{sorted(arches)}")
        _, self.hidden, self.in_features = arches.pop()
        ws, bs = [], []
        for kind in self.kinds:
            w, b = kernel_ops.pack_mlp_params(
                mlps[kind].params, self.in_features, self.hidden)
            ws.append(w)
            bs.append(b)
        self.weights = jnp.stack(ws)          # (K, L, H, H)
        self.biases = jnp.stack(bs)           # (K, L, H)
        self.mlps = dict(mlps)                # normalization + output contract
        self.block_m = block_m
        self.impl = impl

    def score_ms(self, feats_by_kind: Dict[str, np.ndarray]
                 ) -> Dict[str, np.ndarray]:
        """Raw feature rows per kind -> predicted ms per kind, one launch."""
        from repro.kernels import ops as kernel_ops
        import jax.numpy as jnp
        bm = self.block_m
        blocks, kind_of_block, counts = [], [], []
        for kind, feats in feats_by_kind.items():
            x = self.mlps[kind].normalize(feats)
            n = x.shape[0]
            nb = -(-n // bm)
            xp = np.zeros((nb * bm, self.hidden), np.float32)
            xp[:n, :x.shape[1]] = x
            blocks.append(xp)
            kind_of_block.extend([self.kinds.index(kind)] * nb)
            counts.append(n)
        log_ms = np.asarray(kernel_ops.fused_mlp_score(
            jnp.asarray(np.concatenate(blocks)),
            jnp.asarray(np.asarray(kind_of_block, np.int32)),
            self.weights, self.biases, block_m=bm, impl=self.impl))
        out, offset = {}, 0
        for kind, n in zip(feats_by_kind, counts):
            out[kind] = self.mlps[kind].ms_from_log(
                log_ms[offset:offset + n])
            offset += (-(-n // bm)) * bm
        return out


def _resolve_scorer(scorer, mlps: Dict):
    """Map a ``predict_sweep`` scorer spelling to a usable instance.

    ``None``/"off" -> per-kind jitted forwards; "auto" -> fused Pallas
    only on a TPU backend (CPU keeps strict parity with
    ``predict_fleet``), silently falling back when the MLP set is not
    architecture-uniform; an impl name ("pallas" | "interpret" | "jnp")
    forces the fused path (and raises on non-uniform MLPs); a ready
    :class:`FusedMLPScorer` is used as-is.  The single policy shared by
    ``predict_sweep`` and ``HabitatPredictor`` (which only adds caching).
    """
    if scorer is None or scorer == "off" or not mlps:
        return None
    if isinstance(scorer, FusedMLPScorer):
        return scorer
    if scorer == "auto":
        import jax
        if jax.default_backend() != "tpu":
            return None
        try:
            return FusedMLPScorer(mlps, impl="pallas")
        except (ValueError, AttributeError):
            # mixed architectures, or duck-typed MLPs exposing only
            # predict_ms: best-effort falls back to per-kind forwards
            return None
    if scorer in ("pallas", "interpret", "jnp"):
        return FusedMLPScorer(mlps, impl=scorer)
    raise ValueError(f"unknown scorer spelling {scorer!r}")


def predict_sweep(traces: Union[RaggedTraceArrays, Sequence[TrackedTrace]],
                  dests: Union[DeviceArrays, Sequence[str],
                               Sequence[DeviceSpec]],
                  mlps: Optional[Dict] = None,
                  exact: bool = False,
                  model_overhead: bool = False,
                  scorer=None) -> SweepPrediction:
    """Predict every trace on every destination in one ragged pass.

    Row i of the result reproduces :func:`predict_trace_batch` on trace i
    alone.  Wave scaling broadcasts per-op origin arrays through the same
    IEEE expression and the analytical fallback is the same element-wise
    grid function, so those rows agree BITWISE.  Trained-MLP rows go
    through the same per-kind batched forwards (when no fused ``scorer``
    is active) but batch all traces' ops together, so their jitted
    float32 batches pad to different shapes than the per-trace spelling —
    equal to ~1e-6 relative, not bit-for-bit.
    """
    ragged = stack_traces(traces)
    da = devices.as_arrays(dests)
    mlps = mlps or {}
    out = np.empty((ragged.n_ops, da.n), np.float64)

    # kernel-alike: segment-aware wave scaling over the whole ragged grid
    alike = ~ragged.kernel_varying
    if alike.any():
        t_o = ragged.measured_ms[alike]
        if np.isnan(t_o).any():
            bad = int(np.flatnonzero(alike)[np.isnan(t_o).argmax()])
            tid = int(ragged.trace_ids[bad])
            raise ValueError(
                f"trace {ragged.labels[tid]!r} op row "
                f"{bad - int(ragged.offsets[tid])} has no origin "
                f"measurement")
        sub = SimpleNamespace(intensity=ragged.intensity[alike],
                              bytes_accessed=ragged.bytes_accessed[alike])
        out[alike] = wave_scaling.scale_times_vec(
            t_o, sub, ragged.alike_origin_arrays(), da, exact=exact,
            model_overhead=model_overhead)

    # kernel-varying without an MLP: vectorized analytical fallback,
    # computed on the masked rows only (the formula is element-wise, so
    # this matches predict_trace_batch's full-grid-then-mask bitwise)
    kind_has_mlp = np.asarray([k in mlps for k in ragged.kinds], bool)
    no_mlp = ragged.kernel_varying & ~kind_has_mlp[ragged.kind_ids]
    if no_mlp.any():
        sub = SimpleNamespace(
            kernel_varying=ragged.kernel_varying[no_mlp],
            flops=ragged.flops[no_mlp],
            bytes_accessed=ragged.bytes_accessed[no_mlp])
        out[no_mlp] = analytical_ms_vec(sub, da)

    # kernel-varying with an MLP: fused one-launch scorer when available,
    # otherwise the same per-kind batched forwards as predict_trace_batch
    fused = _resolve_scorer(scorer, mlps)
    if fused is not None:
        feats_by_kind: Dict[str, np.ndarray] = {}
        idx_by_kind: Dict[str, np.ndarray] = {}
        for kid, kind in enumerate(ragged.kinds):
            if kind not in mlps:
                continue
            idx = np.flatnonzero(ragged.kernel_varying
                                 & (ragged.kind_ids == kid))
            if not len(idx):
                continue
            idx_by_kind[kind] = idx
            feats_by_kind[kind] = mlp_features_grid(ragged, idx, da)
        if feats_by_kind:
            scored = fused.score_ms(feats_by_kind)
            for kind, idx in idx_by_kind.items():
                out[idx] = scored[kind].reshape(len(idx), da.n)
    else:
        _mlp_scores_per_kind(ragged, da, mlps, out)

    return SweepPrediction(dests=list(da.names), op_ms=out, arrays=ragged)
