"""Analytical per-primitive cost model over jaxpr equations.

This is the TPU-side replacement for the CUPTI-measured metrics Habitat
gathers on GPUs (Sec. 4.2): for every jaxpr equation we compute

  * ``flops``          -- floating point operations
  * ``bytes_accessed`` -- bytes read from + written to HBM (assuming no fusion)
  * arithmetic intensity = flops / bytes_accessed

which feed (i) the roofline-based γ selection (Eq. 3), (ii) the device
simulator, and (iii) the §Roofline deliverable.

The model intentionally over-counts memory traffic relative to a fusing
compiler (each op reads its inputs and writes its output) — this mirrors the
paper's kernel-level view, where every CUDA kernel really does round-trip
through DRAM.
"""

from __future__ import annotations

import dataclasses
import math
from functools import reduce
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.extend import core as jcore


def _size(aval) -> int:
    return int(np.prod(aval.shape)) if aval.shape else 1


def _bytes(aval) -> int:
    dtype = getattr(aval, "dtype", None)
    itemsize = np.dtype(dtype).itemsize if dtype is not None else 4
    return _size(aval) * itemsize


@dataclasses.dataclass
class OpCost:
    flops: float = 0.0
    bytes_read: float = 0.0
    bytes_written: float = 0.0

    @property
    def bytes_accessed(self) -> float:
        return self.bytes_read + self.bytes_written

    @property
    def intensity(self) -> float:
        """Arithmetic intensity (FLOPs/byte); paper Fig. 2's x-axis."""
        return self.flops / max(self.bytes_accessed, 1.0)

    def __add__(self, other: "OpCost") -> "OpCost":
        return OpCost(self.flops + other.flops,
                      self.bytes_read + other.bytes_read,
                      self.bytes_written + other.bytes_written)

    def scaled(self, k: float) -> "OpCost":
        return OpCost(self.flops * k, self.bytes_read * k,
                      self.bytes_written * k)


# FLOPs-per-element for elementwise primitives that are more expensive than
# one op.  Everything else defaults to 1 flop/element.
_ELEMENTWISE_WEIGHT = {
    "exp": 4, "log": 4, "log1p": 4, "expm1": 4,
    "sin": 4, "cos": 4, "tan": 6, "tanh": 6, "logistic": 6,
    "erf": 8, "erf_inv": 8, "erfc": 8,
    "rsqrt": 2, "sqrt": 2, "cbrt": 4,
    "div": 2, "rem": 2, "pow": 8, "integer_pow": 2,
    "atan2": 10, "sigmoid": 6,
}

# Primitives that are pure data movement (no flops, bytes only).
_MOVEMENT = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "rev",
    "concatenate", "slice", "dynamic_slice", "dynamic_update_slice",
    "pad", "gather", "scatter", "convert_element_type", "bitcast_convert_type",
    "copy", "device_put", "split", "expand_dims", "real", "imag", "iota",
    "select_n", "stop_gradient", "squeeze", "rng_bit_generator",
}

# Collective primitives: tracked separately so the distributed predictor and
# the roofline collective term can see them.
_COLLECTIVES = {
    "psum", "pmax", "pmin", "ppermute", "all_gather", "all_to_all",
    "reduce_scatter", "psum_scatter", "pgather", "axis_index",
}


def _dot_general_cost(eqn) -> Tuple[OpCost, Dict[str, int]]:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dnums = eqn.params["dimension_numbers"]
    (lhs_c, rhs_c), (lhs_b, rhs_b) = dnums
    batch = reduce(int.__mul__, (lhs.shape[d] for d in lhs_b), 1)
    contract = reduce(int.__mul__, (lhs.shape[d] for d in lhs_c), 1)
    m = _size(lhs) // max(batch * contract, 1)
    n = _size(rhs) // max(batch * contract, 1)
    flops = 2.0 * batch * m * n * contract
    cost = OpCost(flops,
                  _bytes(lhs) + _bytes(rhs),
                  sum(_bytes(v.aval) for v in eqn.outvars))
    params = {"b": batch, "m": m, "n": n, "k": contract}
    return cost, params


def _conv_cost(eqn) -> Tuple[OpCost, Dict[str, int]]:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    out = eqn.outvars[0].aval
    # flops = 2 * output_size * (reduction per output element)
    dnums = eqn.params["dimension_numbers"]
    rhs_shape = rhs.shape
    # kernel spatial dims * input features per group
    feature_group_count = eqn.params.get("feature_group_count", 1)
    red = _size(rhs) // max(rhs_shape[dnums.rhs_spec[0]], 1)  # per out-channel
    flops = 2.0 * _size(out) * red / max(feature_group_count, 1)
    cost = OpCost(flops, _bytes(lhs) + _bytes(rhs), _bytes(out))
    params = {"out_size": _size(out), "red": red}
    return cost, params


def eqn_cost(eqn) -> Tuple[OpCost, Dict[str, Any]]:
    """Cost of a single jaxpr equation (recursing into sub-jaxprs)."""
    prim = eqn.primitive.name
    params: Dict[str, Any] = {}

    if prim == "dot_general":
        return _dot_general_cost(eqn)
    if prim == "conv_general_dilated":
        return _conv_cost(eqn)

    # Recurse into higher-order primitives.
    if prim == "scan":
        body = eqn.params["jaxpr"]
        length = eqn.params["length"]
        inner = jaxpr_cost(body.jaxpr)
        return inner.scaled(length), {"length": length}
    if prim == "while":
        # Trip count is unknowable statically; assume one iteration of the
        # body (callers that care pass trip-count hints via trace.py).
        inner = jaxpr_cost(eqn.params["body_jaxpr"].jaxpr)
        return inner, {"assumed_trips": 1}
    if prim == "cond":
        branches = eqn.params["branches"]
        costs = [jaxpr_cost(b.jaxpr) for b in branches]
        worst = max(costs, key=lambda c: c.flops + c.bytes_accessed)
        return worst, {"branches": len(branches)}
    if prim in ("pjit", "closed_call", "core_call", "custom_jvp_call",
                "custom_vjp_call", "custom_vjp_call_jaxpr", "remat_call",
                "remat", "checkpoint", "named_call", "custom_lin"):
        sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
        if sub is not None:
            inner_jaxpr = sub.jaxpr if hasattr(sub, "jaxpr") else sub
            return jaxpr_cost(inner_jaxpr), {}
        return OpCost(), {}

    in_bytes = sum(_bytes(v.aval) for v in eqn.invars
                   if hasattr(v, "aval") and not isinstance(v, jcore.Literal))
    out_bytes = sum(_bytes(v.aval) for v in eqn.outvars)

    if prim in _COLLECTIVES:
        # Collective cost: bytes moved over links == operand bytes.
        return OpCost(0.0, in_bytes, out_bytes), {"collective": True}

    if prim in _MOVEMENT:
        return OpCost(0.0, in_bytes, out_bytes), {}

    if prim.startswith("reduce_") or prim in ("argmax", "argmin",
                                              "reduce_precision"):
        flops = float(sum(_size(v.aval) for v in eqn.invars
                          if hasattr(v, "aval")))
        return OpCost(flops, in_bytes, out_bytes), {}
    if prim in ("cumsum", "cumprod", "cummax", "cummin", "cumlogsumexp"):
        flops = float(sum(_size(v.aval) for v in eqn.invars
                          if hasattr(v, "aval")))
        return OpCost(flops, in_bytes, out_bytes), {}
    if prim == "sort":
        n = max((_size(v.aval) for v in eqn.invars if hasattr(v, "aval")),
                default=1)
        flops = float(n) * max(math.log2(max(n, 2)), 1.0)
        return OpCost(flops, in_bytes, out_bytes), {}
    if prim == "top_k":
        n = _size(eqn.invars[0].aval)
        flops = float(n) * max(math.log2(max(eqn.params.get("k", 1), 2)), 1.0)
        return OpCost(flops, in_bytes, out_bytes), {}

    # Default: elementwise with a per-primitive weight.
    weight = _ELEMENTWISE_WEIGHT.get(prim, 1)
    out_size = sum(_size(v.aval) for v in eqn.outvars)
    return OpCost(float(weight * out_size), in_bytes, out_bytes), {}


def jaxpr_cost(jaxpr) -> OpCost:
    """Total cost of a (possibly nested) jaxpr."""
    total = OpCost()
    for eqn in jaxpr.eqns:
        c, _ = eqn_cost(eqn)
        total = total + c
    return total


def fn_cost(fn, *args, **kwargs) -> OpCost:
    """Cost of calling ``fn(*args, **kwargs)`` (traced, never executed)."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return jaxpr_cost(closed.jaxpr)
