"""The MLP execution-time predictors (paper Sec. 3.4 / 4.3.3), in pure JAX.

Architecture (paper defaults): input layer -> 8 hidden layers x 1024 units,
ReLU -> 1 output (predicted fwd+bwd execution time in ms).  Trained with
Adam (lr 5e-4 -> 1e-4 after half the epochs), weight decay 1e-4, batch 512,
MAPE loss:

    L = mean( |pred - measured| / measured )

Layer count / width are configurable for the Fig. 5 sensitivity study.
"""

from __future__ import annotations

import dataclasses
import itertools
import pickle
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import integrity
from repro.core.dataset import Dataset


@dataclasses.dataclass
class MLPConfig:
    in_features: int = 11
    hidden_layers: int = 8
    hidden_size: int = 1024
    epochs: int = 80
    batch_size: int = 512
    lr: float = 5e-4
    lr_after_half: float = 1e-4
    weight_decay: float = 1e-4
    seed: int = 0


def init_params(cfg: MLPConfig) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    key = jax.random.PRNGKey(cfg.seed)
    sizes = ([cfg.in_features] + [cfg.hidden_size] * cfg.hidden_layers + [1])
    params = []
    for i in range(len(sizes) - 1):
        key, sub = jax.random.split(key)
        scale = np.sqrt(2.0 / sizes[i])
        w = jax.random.normal(sub, (sizes[i], sizes[i + 1]),
                              jnp.float32) * scale
        b = jnp.zeros((sizes[i + 1],), jnp.float32)
        params.append((w, b))
    return params


def forward(params, x: jnp.ndarray) -> jnp.ndarray:
    h = x
    for w, b in params[:-1]:
        h = jax.nn.relu(h @ w + b)
    w, b = params[-1]
    return (h @ w + b)[..., 0]


#: jitted inference entry point: the fleet engine issues one batched
#: forward per op kind covering every destination device, so dispatch
#: overhead (not FLOPs) dominates without jit.  Shapes recompile per
#: (batch size, width) pair; the fleet path reuses a handful of shapes.
_forward_jit = jax.jit(forward)


def mape_loss(params, x, y) -> jnp.ndarray:
    """MAPE against raw times; the network predicts log(ms)."""
    pred = jnp.exp(forward(params, x))
    return jnp.mean(jnp.abs(pred - y) / jnp.maximum(y, 1e-9))


def male_loss(params, x, logy) -> jnp.ndarray:
    """Mean-absolute-log-error: the scale-free training surrogate.

    |log pred - log y| ≈ MAPE for small errors but is numerically stable
    across the ~6 orders of magnitude our op times span (stabilization
    choice on top of the paper's raw-MAPE; evaluation still reports MAPE)."""
    return jnp.mean(jnp.abs(forward(params, x) - logy))


#: monotonic TrainedMLP identity for result-cache keys.  ``id()`` is unsafe
#: here: CPython recycles addresses, so a retrained model could alias a
#: stale cache entry minted for its garbage-collected predecessor.
_UID = itertools.count()

#: finite ceiling for the network's log(ms) output.  float64 ``exp``
#: overflows to inf past ~709.78, and an inf prediction poisons every
#: downstream consumer (ranks, result caches, sqlite/netcache entries).
#: Out-of-distribution features must saturate to a huge-but-finite time
#: (e^80 ~ 5.5e34 ms — last in any ranking) instead.  In-distribution
#: log(ms) sits in roughly [-7, 12], so the clamp never moves a sane
#: prediction.
LOG_MS_MAX = 80.0


@dataclasses.dataclass
class TrainedMLP:
    kind: str
    cfg: MLPConfig
    params: list
    feature_mean: np.ndarray
    feature_std: np.ndarray
    test_mape: float = float("nan")
    uid: int = dataclasses.field(default_factory=lambda: next(_UID))

    def normalize(self, features: np.ndarray) -> np.ndarray:
        """Standardize raw feature rows with this model's train-set stats.

        Shared by the per-kind inference path and the fused multi-kind
        scorer (``core.batched.FusedMLPScorer``) so the two cannot drift."""
        return ((np.atleast_2d(features) - self.feature_mean)
                / self.feature_std)

    @staticmethod
    def ms_from_log(log_ms: np.ndarray) -> np.ndarray:
        """Map the network's log(ms) output to clamped milliseconds —
        the one output contract for every inference path.

        Clamped on both ends: ``LOG_MS_MAX`` keeps extreme features from
        overflowing ``exp`` into inf (which would poison ranks and
        result caches), and the 1e-6 floor keeps a negative blow-up from
        predicting zero time."""
        return np.maximum(np.exp(np.minimum(log_ms, LOG_MS_MAX)), 1e-6)

    def predict_ms(self, features: np.ndarray) -> np.ndarray:
        x = self.normalize(features)
        # bucket the batch size so the jitted forward compiles a bounded
        # set of shapes, not one per distinct trace: powers of two up to
        # 512, multiples of 512 beyond (keeps padding waste under ~20%
        # for the large fleet-grid batches)
        n = x.shape[0]
        if n <= 512:
            padded = 1 << max(n - 1, 0).bit_length()
        else:
            padded = -(-n // 512) * 512
        if padded != n:
            x = np.concatenate(
                [x, np.zeros((padded - n, x.shape[1]), x.dtype)])
        out = np.asarray(_forward_jit(self.params,
                                      jnp.asarray(x, jnp.float32)))[:n]
        return self.ms_from_log(out)

    def save(self, path: Path) -> None:
        path.parent.mkdir(parents=True, exist_ok=True)
        blob = {"kind": self.kind, "cfg": dataclasses.asdict(self.cfg),
                "params": [(np.asarray(w), np.asarray(b))
                           for w, b in self.params],
                "mean": self.feature_mean, "std": self.feature_std,
                "test_mape": self.test_mape}
        with open(path, "wb") as f:
            f.write(integrity.seal(pickle.dumps(blob)))

    @staticmethod
    def load(path: Path) -> "TrainedMLP":
        """Load a sealed artifact (``integrity.IntegrityError`` on a
        checksum mismatch — ``predictor.train_mlps`` treats that as
        missing and retrains).  Raw-pickle artifacts written before the
        integrity envelope existed (e.g. the CI artifact cache) still
        load; they are re-sealed the next time they are saved."""
        with open(path, "rb") as f:
            raw = f.read()
        if integrity.is_sealed(raw):
            blob = pickle.loads(integrity.unseal(raw))
        else:                           # legacy pre-envelope artifact
            blob = pickle.loads(raw)
        return TrainedMLP(
            kind=blob["kind"], cfg=MLPConfig(**blob["cfg"]),
            params=[(jnp.asarray(w), jnp.asarray(b))
                    for w, b in blob["params"]],
            feature_mean=blob["mean"], feature_std=blob["std"],
            test_mape=blob["test_mape"])


def _adam_init(params):
    zeros = lambda p: [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in p]
    return zeros(params), zeros(params)


@jax.jit
def _train_step(params, m, v, x, logy, lr, wd, t):
    loss, grads = jax.value_and_grad(male_loss)(params, x, logy)
    b1, b2, eps = 0.9, 0.999, 1e-8
    new_params, new_m, new_v = [], [], []
    for (w, b), (gw, gb), (mw, mb), (vw, vb) in zip(params, grads, m, v):
        mw = b1 * mw + (1 - b1) * gw
        mb = b1 * mb + (1 - b1) * gb
        vw = b2 * vw + (1 - b2) * gw**2
        vb = b2 * vb + (1 - b2) * gb**2
        mhw, mhb = mw / (1 - b1**t), mb / (1 - b1**t)
        vhw, vhb = vw / (1 - b2**t), vb / (1 - b2**t)
        w = w - lr * (mhw / (jnp.sqrt(vhw) + eps) + wd * w)
        b = b - lr * mhb / (jnp.sqrt(vhb) + eps)
        new_params.append((w, b))
        new_m.append((mw, mb))
        new_v.append((vw, vb))
    return new_params, new_m, new_v, loss


def train(dataset: Dataset, cfg: Optional[MLPConfig] = None,
          verbose: bool = False) -> TrainedMLP:
    """Train one MLP predictor on one kernel-varying op's dataset."""
    cfg = cfg or MLPConfig()
    norm = dataset.normalized()
    train_ds, test_ds = norm.split(0.8, seed=cfg.seed)
    cfg = dataclasses.replace(cfg, in_features=train_ds.x.shape[1])
    params = init_params(cfg)
    m, v = _adam_init(params)
    n = len(train_ds.y)
    rng = np.random.default_rng(cfg.seed)
    logy = np.log(np.maximum(train_ds.y, 1e-9))
    step = 0
    for epoch in range(cfg.epochs):
        lr = cfg.lr if epoch < cfg.epochs // 2 else cfg.lr_after_half
        perm = rng.permutation(n)
        for start in range(0, n, cfg.batch_size):
            idx = perm[start:start + cfg.batch_size]
            step += 1
            params, m, v, loss = _train_step(
                params, m, v,
                jnp.asarray(train_ds.x[idx]), jnp.asarray(logy[idx]),
                jnp.float32(lr), jnp.float32(cfg.weight_decay),
                jnp.float32(step))
        if verbose and (epoch % 10 == 0 or epoch == cfg.epochs - 1):
            print(f"  [{dataset.kind}] epoch {epoch:3d} loss {float(loss):.4f}")
    test_mape = float(mape_loss(params, jnp.asarray(test_ds.x),
                                jnp.asarray(test_ds.y)))
    return TrainedMLP(kind=dataset.kind, cfg=cfg, params=params,
                      feature_mean=norm.feature_mean,
                      feature_std=norm.feature_std, test_mape=test_mape)
