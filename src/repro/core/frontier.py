"""Vectorized Pareto-frontier math for time-vs-cost fleet search.

The what-if optimizer (:mod:`repro.serve.optimizer`) prunes each search
generation by *dominance*: a candidate configuration survives only if no
other candidate is at least as good on both objectives (iteration /
epoch time, fleet $/hour) and strictly better on one.  The frontier is
the set of survivors — the only configurations a rational buyer would
pick, whatever their time/cost trade-off.

Everything here is plain NumPy over flat ``(times, costs)`` arrays, so
pruning a generation of hundreds of candidates costs microseconds —
dominance math must never be the reason to price fewer candidates
against the engine.

NaN-cost contract (the ``DeviceSpec.cost_per_hour=None`` devices, which
flow through ``DeviceArrays.cost_per_hour`` as NaN): NumPy comparisons
against NaN are silently ``False``, so naive frontier math would either
drop unrentable devices entirely or — worse — keep everything they
should dominate.  The rule here is explicit: **a NaN cost is treated as
"+inf dollars" for dominance**.  An unrentable device therefore stays
on the frontier exactly when it wins on *time alone* (nothing cheaper-
or-equal is as fast), and it can never knock a priced device off the
cost axis.  ``rank`` paths exclude NaN costs from the $-frontier
explicitly (see ``frontier_indices(..., objective="cost")``).  NaN
*times* are a caller bug and raise.
"""

from __future__ import annotations

import math
from typing import Sequence, Tuple

import numpy as np

__all__ = ["dominates", "frontier_indices", "pareto_mask", "thin_indices"]


def _as_objectives(times, costs) -> Tuple[np.ndarray, np.ndarray]:
    t = np.asarray(times, np.float64).reshape(-1)
    c = np.asarray(costs, np.float64).reshape(-1)
    if t.shape != c.shape:
        raise ValueError(f"times {t.shape} and costs {c.shape} differ")
    if np.isnan(t).any():
        raise ValueError("NaN time objective (unpredicted candidate?) — "
                         "frontier math needs every time finite")
    return t, c


def dominates(time_a: float, cost_a: float,
              time_b: float, cost_b: float) -> bool:
    """Reference (scalar) dominance: does A weakly dominate B?

    A dominates B iff A is <= on both objectives and < on at least one.
    NaN costs compare as +inf (the module contract), so a priced device
    strictly dominates an equally-fast unrentable one, and two
    unrentable devices compare on time alone.  This is the semantics
    :func:`pareto_mask` implements vectorized; the property suite checks
    them against each other."""
    ca = math.inf if math.isnan(cost_a) else cost_a
    cb = math.inf if math.isnan(cost_b) else cost_b
    return (time_a <= time_b and ca <= cb
            and (time_a < time_b or ca < cb))


def pareto_mask(times, costs) -> np.ndarray:
    """Boolean mask of non-dominated points (vectorized, O(n log n)).

    ``times`` must be finite; ``costs`` may contain NaN (treated as
    +inf — kept only via the time-only frontier) or +inf.  Duplicate
    points (equal on both objectives) do not dominate each other, so
    *all* copies of a surviving point are kept — the caller sees every
    candidate that achieved the frontier, not an arbitrary winner."""
    t, c = _as_objectives(times, costs)
    if t.size == 0:
        return np.zeros(0, bool)
    c_eff = np.where(np.isnan(c), np.inf, c)
    # unique rows come back lexsorted by (time, cost); within the unique
    # set weak dominance reduces to "strictly cheaper than everything
    # earlier in the sort" (an equal-time row with higher cost is
    # dominated by the cost term; a later-time row needs a strictly
    # lower cost than every earlier row to be incomparable with all of
    # them).  Duplicates collapse onto one row and share its verdict.
    pts = np.stack([t, c_eff], axis=1)
    uniq, inverse = np.unique(pts, axis=0, return_inverse=True)
    run_min = np.minimum.accumulate(uniq[:, 1])
    prev_min = np.concatenate(([np.inf], run_min[:-1]))
    keep = uniq[:, 1] < prev_min
    keep[0] = True      # nothing precedes the first row — even at inf cost
    return keep[inverse.reshape(-1)]


def frontier_indices(times, costs, objective: str = "pareto") -> np.ndarray:
    """Indices of the frontier, in deterministic order.

    ``objective``:

    * ``"pareto"`` — the 2-D time/cost frontier (NaN costs ride the
      time-only frontier, per the module contract).
    * ``"time"``  — pure speed: every index achieving the minimum time.
    * ``"cost"``  — the $-frontier: NaN-cost points are **excluded
      explicitly** (an unrentable device has no dollars axis to win on),
      then every index achieving the minimum cost among the rest.

    Ordering is (time asc, cost asc, index asc) — stable across runs and
    across any permutation-invariant caller, so search results and wire
    payloads are reproducible byte for byte."""
    t, c = _as_objectives(times, costs)
    if objective == "pareto":
        idx = np.flatnonzero(pareto_mask(t, c))
    elif objective == "time":
        idx = np.flatnonzero(t == t.min()) if t.size else np.zeros(0, int)
    elif objective == "cost":
        priced = ~np.isnan(c)
        if not priced.any():
            return np.zeros(0, np.int64)
        best = np.nanmin(np.where(priced, c, np.nan))
        idx = np.flatnonzero(priced & (c == best))
    else:
        raise ValueError(f"unknown frontier objective {objective!r}")
    c_eff = np.where(np.isnan(c), np.inf, c)
    order = np.lexsort((idx, c_eff[idx], t[idx]))
    return idx[order].astype(np.int64)


def thin_indices(ordered: Sequence[int], cap: int) -> np.ndarray:
    """Cap a frontier at ``cap`` points, keeping its shape.

    ``ordered`` is a frontier already in (time asc, ...) order (the
    output of :func:`frontier_indices`); thinning keeps both endpoints
    (the fastest and the cheapest survivor) and evenly-spaced interior
    points, so a capped frontier still spans the same trade-off range
    instead of clustering at one end.  Deterministic — pure index
    arithmetic, no RNG."""
    ordered = np.asarray(ordered, np.int64).reshape(-1)
    if cap <= 0:
        raise ValueError(f"frontier cap must be positive (got {cap})")
    if ordered.size <= cap:
        return ordered
    if cap == 1:
        return ordered[:1]
    pick = np.round(np.linspace(0, ordered.size - 1, cap)).astype(np.int64)
    return ordered[np.unique(pick)]
