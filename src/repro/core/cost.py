"""Throughput / cost-normalized-throughput analysis (paper Sec. 5.3).

Habitat's end use: given a predicted iteration time per candidate device,
compute training throughput (samples/s) and cost-normalized throughput
(samples/s/$) and *rank* the candidates — the case studies show the ranking
is what users act on, and it survives moderate prediction error.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core import devices
from repro.core.trace import TrackedTrace


def throughput(batch_size: int, iter_ms: float) -> float:
    """Training samples per second."""
    return batch_size / (iter_ms * 1e-3)


def cost_normalized_throughput(batch_size: int, iter_ms: float,
                               cost_per_hour: float) -> float:
    """Samples per dollar (samples/s divided by $/s).

    A price of 0.0 (free tier / hardware already owned) yields ``inf`` —
    a legitimately free device dominates every paid one on samples/$."""
    if cost_per_hour == 0.0:
        return float("inf")
    return throughput(batch_size, iter_ms) / (cost_per_hour / 3600.0)


@dataclasses.dataclass
class DeviceChoice:
    device: str
    iter_ms: float
    throughput: float
    cost_per_hour: Optional[float]
    cost_normalized: Optional[float]
    speedup_vs_origin: float


def rank_devices(trace: TrackedTrace, batch_size: int,
                 candidates: Sequence[str],
                 predictor=None, by: str = "throughput") -> List[DeviceChoice]:
    """Predict and rank candidate devices for the traced workload.

    ``by`` is either "throughput" (maximize speed) or "cost" (maximize
    samples/$) — the two user objectives from case studies 1 and 2.

    Predictors exposing ``predict_fleet`` (all predictors in
    ``repro.core.predictor``) are queried once for the whole candidate set
    via the vectorized engine; anything else falls back to the per-device
    ``predict_trace`` loop."""
    candidates = list(candidates)   # may be a one-shot iterator
    origin_ms = trace.run_time_ms
    if predictor is None:
        from repro.core import predictor as predictor_mod
        predictor = predictor_mod.default_predictor()
    if hasattr(predictor, "predict_fleet"):
        fleet_ms = predictor.predict_fleet(trace, candidates).as_dict()
    else:
        fleet_ms = {name: trace.to_device(name,
                                          predictor=predictor).run_time_ms
                    for name in candidates}
    out: List[DeviceChoice] = []
    for name in candidates:
        spec = devices.get(name)
        ms = fleet_ms[name]
        tput = throughput(batch_size, ms)
        # `is not None`, not truthiness: a free device (0.0 $/hr) is
        # rentable and ranks at inf samples/$, it is not unpriced
        cn = (cost_normalized_throughput(batch_size, ms, spec.cost_per_hour)
              if spec.cost_per_hour is not None else None)
        out.append(DeviceChoice(
            device=name, iter_ms=ms, throughput=tput,
            cost_per_hour=spec.cost_per_hour, cost_normalized=cn,
            speedup_vs_origin=origin_ms / ms))
    if by == "cost":
        out.sort(key=lambda c: -(c.cost_normalized or 0.0))
    else:
        out.sort(key=lambda c: -c.throughput)
    return out


def format_ranking(choices: Sequence[DeviceChoice]) -> str:
    lines = [f"{'device':<12} {'iter ms':>9} {'samples/s':>10} "
             f"{'$/hr':>6} {'samples/$':>10} {'speedup':>8}"]
    for c in choices:
        lines.append(
            f"{c.device:<12} {c.iter_ms:>9.2f} {c.throughput:>10.1f} "
            f"{(f'{c.cost_per_hour:.2f}' if c.cost_per_hour is not None else '-'):>6} "
            f"{(f'{c.cost_normalized:.0f}' if c.cost_normalized is not None else '-'):>10} "
            f"{c.speedup_vs_origin:>7.2f}x")
    return "\n".join(lines)
