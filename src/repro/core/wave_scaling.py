"""Wave scaling (paper Sec. 3.3) and roofline-based γ selection (Sec. 4.2).

Equation 1 (exact, with wave quantization):

    T_d = ceil(B/W_d) * ((D_o/D_d) * (W_d/W_o))^γ * (C_o/C_d)^(1-γ)
          * ceil(B/W_o)^(-1) * T_o

Equation 2 (the large-B limit Habitat uses in practice):

    T_d = (D_o/D_d)^γ * (W_o/W_d)^(1-γ) * (C_o/C_d)^(1-γ) * T_o

Equation 3 (γ from arithmetic intensity x and destination ridge point R):

    γ = 1 - 0.5 x / R          if x <  R      (memory-bandwidth bound side)
    γ = 0.5 R / x              otherwise      (compute bound side)

On TPUs the "wave" is a wave of VMEM grid tiles rather than thread blocks
(see DESIGN.md §2); ``B`` is derived from the op's memory footprint and a
VMEM-sized tile, ``W_i`` from the device spec.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Union

import numpy as np

from repro.core import devices as devices_mod
from repro.core.devices import DeviceArrays, DeviceSpec
from repro.core.trace import Op

#: Working-set bytes of one grid tile (a thread block's slice on GPUs; an
#: 8x128-lane VMEM sub-tile batch on TPUs).  The same constant is used by the
#: simulator so the exact Eq. 1 is testable against it.
TILE_BYTES = 64.0 * 1024


def num_tiles(op: Op) -> int:
    """B: the number of grid tiles ("thread blocks") of an op."""
    return max(1, int(math.ceil(op.cost.bytes_accessed / TILE_BYTES)))


def gamma(op: Op, dest: DeviceSpec) -> float:
    """Eq. 3.  γ ∈ [0, 1]: 1 = fully memory-bandwidth bound."""
    x = op.cost.intensity
    r = dest.ridge_point
    if x <= 0.0:
        return 1.0
    if x < r:
        return 1.0 - 0.5 * x / r
    return 0.5 * r / x


#: per-kernel dispatch overhead in ms (matches simulator._LAUNCH_OVERHEAD_MS)
DISPATCH_OVERHEAD_MS = {"gpu": 5e-3, "tpu": 1.5e-3, "trainium": 2e-3,
                        "cpu": 2e-2}


def scale_time(t_o_ms: float, op: Op, origin: DeviceSpec, dest: DeviceSpec,
               exact: bool = False, gamma_override: Optional[float] = None,
               model_overhead: bool = False) -> float:
    """Scale a measured time T_o from ``origin`` to ``dest`` (Eq. 1 / Eq. 2).

    ``model_overhead`` (beyond paper): treat the fixed kernel dispatch
    latency as unscalable — subtract the origin's before scaling, add the
    destination's after.  Matters for launch-bound small kernels."""
    g = gamma(op, dest) if gamma_override is None else gamma_override
    d_ratio = origin.mem_bandwidth / dest.mem_bandwidth
    c_ratio = origin.clock_hz / dest.clock_hz
    w_o, w_d = origin.wave_size, dest.wave_size
    if exact:
        b = num_tiles(op)
        waves_d = math.ceil(b / w_d)
        waves_o = math.ceil(b / w_o)
        factor = (waves_d
                  * (d_ratio * (w_d / w_o)) ** g
                  * c_ratio ** (1.0 - g)
                  / waves_o)
    else:
        factor = (d_ratio ** g
                  * (w_o / w_d) ** (1.0 - g)
                  * c_ratio ** (1.0 - g))
    if model_overhead:
        oh_o = DISPATCH_OVERHEAD_MS[origin.kind]
        oh_d = DISPATCH_OVERHEAD_MS[dest.kind]
        return max(t_o_ms - oh_o, 0.0) * factor + oh_d
    return t_o_ms * factor


def flops_ratio_heuristic(t_o_ms: float, origin: DeviceSpec,
                          dest: DeviceSpec) -> float:
    """The naive peak-FLOPS-ratio baseline the paper debunks (Fig. 1)."""
    return t_o_ms * origin.peak_flops / dest.peak_flops


# ---------------------------------------------------------------------------
# Vectorized fleet path: Eqs. 1-3 over an (n_ops x n_devices) grid at once.
# ---------------------------------------------------------------------------
def _gamma_core(x: np.ndarray, r: np.ndarray) -> np.ndarray:
    """Eq. 3 on broadcast-ready arrays.

    The one γ expression shared by the grid and flat-cell spellings: each
    output element is produced by the same IEEE operation sequence
    regardless of the input shapes, so ``gamma_vec(x, r)[i, j]`` equals
    the flat evaluation on ``(x[i], r[j])`` bitwise."""
    with np.errstate(divide="ignore", invalid="ignore"):
        g = np.where(x < r, 1.0 - 0.5 * x / r,
                     0.5 * r / np.where(x > 0.0, x, 1.0))
    return np.where(x <= 0.0, 1.0, g)


def gamma_vec(intensity: np.ndarray, ridge: np.ndarray) -> np.ndarray:
    """Eq. 3 for every (op, destination) pair.

    ``intensity`` is (n_ops,) arithmetic intensities, ``ridge`` (n_dev,)
    destination ridge points; returns γ with shape (n_ops, n_dev)."""
    return _gamma_core(np.asarray(intensity, np.float64)[:, None],
                       np.asarray(ridge, np.float64)[None, :])


def num_tiles_vec(bytes_accessed: np.ndarray) -> np.ndarray:
    """Vectorized ``num_tiles``: B per op, shape (n_ops,)."""
    b = np.ceil(np.asarray(bytes_accessed, np.float64) / TILE_BYTES)
    return np.maximum(b, 1.0)


def wave_factor_vec(ops_arrays,
                    origin: Union[DeviceSpec,
                                  "devices_mod.OriginArrays"],
                    dests: Union[DeviceArrays, Sequence[DeviceSpec]],
                    exact: bool = False,
                    gamma_override: Optional[float] = None) -> np.ndarray:
    """The t-independent scaling-factor grid of :func:`scale_times_vec`.

    Element [i, j] is the multiplier applied to op i's measured time to
    land on device j — a pure function of the (immutable) op arrays and
    the destination fleet, which is why the sweep engine caches it per
    (stack, fleet) and repeat sweeps skip the pow-heavy recompute.
    Splitting the factor out of :func:`scale_times_vec` changes no
    operation order: the final ``t * factor`` combine is exactly the
    expression the unsplit spelling ended with."""
    da = devices_mod.as_arrays(dests)
    if gamma_override is None:
        g = gamma_vec(ops_arrays.intensity, da.ridge_point)
    else:
        g = np.full((len(np.atleast_1d(ops_arrays.intensity)), da.n),
                    float(gamma_override))
    # origin-side columns: (1, 1) for a single spec, (n_ops, 1) per-op
    o_bw = np.atleast_1d(np.asarray(origin.mem_bandwidth,
                                    np.float64))[:, None]
    o_ck = np.atleast_1d(np.asarray(origin.clock_hz, np.float64))[:, None]
    o_w = np.atleast_1d(np.asarray(origin.wave_size, np.float64))[:, None]
    d_ratio = o_bw / da.mem_bandwidth[None, :]
    c_ratio = o_ck / da.clock_hz[None, :]
    w_d = da.wave_size
    if exact:
        b = num_tiles_vec(ops_arrays.bytes_accessed)           # (n_ops,)
        waves_d = np.ceil(b[:, None] / w_d[None, :])
        waves_o = np.ceil(b[:, None] / o_w)
        return (waves_d
                * (d_ratio * (w_d[None, :] / o_w)) ** g
                * c_ratio ** (1.0 - g)
                / waves_o)
    return (d_ratio ** g
            * (o_w / w_d[None, :]) ** (1.0 - g)
            * c_ratio ** (1.0 - g))


def dispatch_overheads(origin: Union[DeviceSpec,
                                     "devices_mod.OriginArrays"],
                       dests: DeviceArrays):
    """(origin, destination) dispatch-overhead terms of the overhead
    model: scalar-or-(n_ops,) on the origin side, (n_dev,) per dest."""
    if isinstance(origin, DeviceSpec):
        oh_o = DISPATCH_OVERHEAD_MS[origin.kind]
    else:
        oh_o = np.asarray([DISPATCH_OVERHEAD_MS[k] for k in origin.kinds],
                          np.float64)
    oh_d = np.asarray([DISPATCH_OVERHEAD_MS[k] for k in dests.kinds],
                      np.float64)
    return oh_o, oh_d


def combine_wave_factor(t_o_ms: np.ndarray, factor: np.ndarray,
                        overheads=None) -> np.ndarray:
    """Apply a (possibly cached) factor grid to measured times — the
    final combine of :func:`scale_times_vec`, shared so cached-factor
    sweeps stay bitwise-identical to the unsplit spelling."""
    t = np.atleast_1d(np.asarray(t_o_ms, np.float64))
    if overheads is not None:
        oh_o, oh_d = overheads
        return (np.maximum(t - oh_o, 0.0)[:, None] * factor
                + oh_d[None, :])
    return t[:, None] * factor


def scale_times_vec(t_o_ms: np.ndarray, ops_arrays,
                    origin: Union[DeviceSpec,
                                  "devices_mod.OriginArrays"],
                    dests: Union[DeviceArrays, Sequence[DeviceSpec]],
                    exact: bool = False,
                    gamma_override: Optional[float] = None,
                    model_overhead: bool = False) -> np.ndarray:
    """Vectorized :func:`scale_time`: one (n_ops x n_devices) grid at once.

    ``ops_arrays`` is a structure of arrays exposing ``intensity`` and
    ``bytes_accessed`` (``TrackedTrace.to_arrays()`` produces one); element
    [i, j] equals ``scale_time(t_o_ms[i], ops[i], origin, dests[j], ...)``.

    ``origin`` is segment-aware: a single :class:`DeviceSpec` (every op was
    measured on the same device) or an :class:`~repro.core.devices.
    OriginArrays` with one row per op (ragged multi-trace stacks, where
    origins differ per trace).  The origin terms broadcast as (1, n_dev) or
    (n_ops, n_dev); each grid element is computed by the exact same IEEE
    operation sequence either way, so the two spellings agree bitwise.
    """
    da = devices_mod.as_arrays(dests)
    factor = wave_factor_vec(ops_arrays, origin, da, exact=exact,
                             gamma_override=gamma_override)
    overheads = dispatch_overheads(origin, da) if model_overhead else None
    return combine_wave_factor(t_o_ms, factor, overheads)


def scale_times_flat(t_o_ms: np.ndarray, ops_arrays,
                     origin: "devices_mod.OriginArrays",
                     dests: Union[DeviceArrays, Sequence[DeviceSpec]],
                     dest_idx: np.ndarray,
                     exact: bool = False,
                     gamma_override: Optional[float] = None,
                     model_overhead: bool = False) -> np.ndarray:
    """Wave scaling over a flat list of (op, device) cells, shape (M,).

    The partial-compute spelling of :func:`scale_times_vec` used by the
    cell-masked sweep engine: every input is *per cell* — ``t_o_ms`` and
    the ``ops_arrays`` rows are already gathered to one entry per cell,
    ``origin`` is an :class:`~repro.core.devices.OriginArrays` with one
    row per cell, and ``dest_idx[k]`` selects the destination device of
    cell ``k``.  Cell ``k`` is computed by the exact same IEEE operation
    sequence as grid element ``[i, j]`` of ``scale_times_vec`` (both are
    pure element-wise broadcasts of the same ufuncs), so a masked sweep
    reproduces the full-grid values BITWISE on this path.
    """
    da = devices_mod.as_arrays(dests)
    j = np.asarray(dest_idx, np.intp)
    t = np.asarray(t_o_ms, np.float64)
    d_bw, d_ck = da.mem_bandwidth[j], da.clock_hz[j]
    w_d = da.wave_size[j]
    if gamma_override is None:
        g = _gamma_core(np.asarray(ops_arrays.intensity, np.float64),
                        da.ridge_point[j])
    else:
        g = np.full(t.shape, float(gamma_override))
    o_bw = np.asarray(origin.mem_bandwidth, np.float64)
    o_ck = np.asarray(origin.clock_hz, np.float64)
    o_w = np.asarray(origin.wave_size, np.float64)
    d_ratio = o_bw / d_bw
    c_ratio = o_ck / d_ck
    if exact:
        b = num_tiles_vec(ops_arrays.bytes_accessed)
        waves_d = np.ceil(b / w_d)
        waves_o = np.ceil(b / o_w)
        factor = (waves_d
                  * (d_ratio * (w_d / o_w)) ** g
                  * c_ratio ** (1.0 - g)
                  / waves_o)
    else:
        factor = (d_ratio ** g
                  * (o_w / w_d) ** (1.0 - g)
                  * c_ratio ** (1.0 - g))
    if model_overhead:
        oh_o = np.asarray([DISPATCH_OVERHEAD_MS[k] for k in origin.kinds],
                          np.float64)
        oh_d = np.asarray([DISPATCH_OVERHEAD_MS[k] for k in da.kinds],
                          np.float64)[j]
        return np.maximum(t - oh_o, 0.0) * factor + oh_d
    return t * factor
