"""Real wall-clock measurement of tracked ops on the host device.

This is the genuinely *runtime-based* half of the reproduction: the paper
measures each operation's execution time on the GPU the user already has by
re-running it in isolation (Sec. 4.1, "Execution time").  Here the device
the user "already has" is the container's CPU; we rebuild each tracked op
as a standalone jitted callable with the recorded shapes and time it with
the paper's protocol (3 discarded warm-up runs, then the average of 3
measured runs).

Ops we cannot faithfully rebuild in isolation fall back to the simulator
with the cpu-host spec (and are flagged, so callers can report coverage).
"""

from __future__ import annotations

import time
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import devices, simulator
from repro.core.trace import Op, TrackedTrace

WARMUP = 3
REPS = 3

_ELEMENTWISE = {
    "add": jnp.add, "sub": jnp.subtract, "mul": jnp.multiply,
    "div": jnp.divide, "max": jnp.maximum, "min": jnp.minimum,
    "pow": jnp.power,
}
_UNARY = {
    "exp": jnp.exp, "log": jnp.log, "tanh": jnp.tanh, "neg": jnp.negative,
    "rsqrt": jax.lax.rsqrt, "sqrt": jnp.sqrt, "logistic": jax.nn.sigmoid,
    "erf": jax.lax.erf, "abs": jnp.abs, "sign": jnp.sign,
    "integer_pow": lambda x: x * x, "cos": jnp.cos, "sin": jnp.sin,
}


def _time_callable(fn: Callable, *args) -> float:
    jfn = jax.jit(fn)
    out = jfn(*args)
    jax.block_until_ready(out)
    for _ in range(WARMUP - 1):
        jax.block_until_ready(jfn(*args))
    t0 = time.perf_counter()
    for _ in range(REPS):
        jax.block_until_ready(jfn(*args))
    return (time.perf_counter() - t0) / REPS * 1e3  # ms


def _rand(shape, dtype="float32"):
    rng = np.random.default_rng(0)
    if np.issubdtype(np.dtype(dtype), np.floating):
        return jnp.asarray(rng.standard_normal(shape), dtype)
    return jnp.asarray(rng.integers(0, 2, shape), dtype)


def build_callable(op: Op) -> Optional[Tuple[Callable, tuple]]:
    """Rebuild a representative standalone callable for ``op``."""
    p = op.params
    if op.kind == "linear":
        a = _rand((p["m"], p["k"]))
        b = _rand((p["k"], p["n"]))
        return jnp.matmul, (a, b)
    if op.kind == "bmm":
        a = _rand((p["b"], p["m"], p["k"]))
        b = _rand((p["b"], p["k"], p["n"]))
        return jnp.matmul, (a, b)
    if op.kind == "conv2d":
        x = _rand((p["batch"], p["in_ch"], p["image"], p["image"]))
        w = _rand((p["out_ch"], p["in_ch"], p["kernel"], p["kernel"]))
        fn = partial(jax.lax.conv_general_dilated,
                     window_strides=(p["stride"], p["stride"]),
                     padding=[(p["padding"], p["padding"])] * 2)
        return fn, (x, w)
    if op.kind == "recurrent":
        x = _rand((p["seq"], p["batch"], p["in_f"]))
        w = _rand((p["in_f"] + p["hidden"], p["hidden"]))
        h0 = _rand((p["batch"], p["hidden"]))

        def rnn(x, w, h0):
            def step(h, xt):
                h = jnp.tanh(jnp.concatenate([xt, h], -1) @ w)
                return h, h
            return jax.lax.scan(step, h0, x)
        return rnn, (x, w, h0)
    if op.name in _UNARY and op.in_shapes:
        return _UNARY[op.name], (_rand(op.in_shapes[0], op.dtype),)
    if op.name in _ELEMENTWISE and len(op.in_shapes) >= 2:
        return _ELEMENTWISE[op.name], (_rand(op.in_shapes[0], op.dtype),
                                       _rand(op.in_shapes[1], op.dtype))
    if op.name.startswith("reduce_") and op.in_shapes:
        return jnp.sum, (_rand(op.in_shapes[0], op.dtype),)
    return None


def measure_op_ms(op: Op) -> Tuple[float, bool]:
    """(ms, measured_for_real) for one op on the host CPU."""
    built = build_callable(op)
    if built is None:
        return simulator.op_time_ms(op, devices.CPU_HOST), False
    fn, args = built
    try:
        return _time_callable(fn, *args), True
    except Exception:
        return simulator.op_time_ms(op, devices.CPU_HOST), False


def measure_trace_inplace(trace: TrackedTrace) -> float:
    """Fill ``measured_ms`` on every op by real host measurement.

    Returns the fraction of iteration time covered by real measurements."""
    real_ms = total_ms = 0.0
    for op in trace.ops:
        ms, real = measure_op_ms(op)
        op.measured_ms = ms
        total_ms += ms * op.multiplicity
        if real:
            real_ms += ms * op.multiplicity
    return real_ms / max(total_ms, 1e-12)


def calibrate_host_spec() -> dict:
    """Measure the host's achieved GEMM rate and memory bandwidth.

    Habitat ships measured bandwidths in its config file (Sec. 3.3); this is
    the equivalent measurement pass for the host device."""
    n = 1024
    a = _rand((n, n))
    gemm_ms = _time_callable(jnp.matmul, a, a)
    flops = 2.0 * n**3 / (gemm_ms * 1e-3)
    big = _rand((64 * 1024 * 1024 // 4,))  # 64 MiB
    copy_ms = _time_callable(lambda x: x + 1.0, big)
    bw = 2.0 * big.size * 4 / (copy_ms * 1e-3)
    return {"peak_flops": flops, "mem_bandwidth": bw}
