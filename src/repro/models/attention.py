"""Attention: GQA with RoPE, qk-norm, sliding windows; flash (chunked) and
dense paths; decode path against a KV cache.

The chunked path is an online-softmax (flash) algorithm in pure jnp: it
never materializes the full (Sq, Skv) score matrix, which is what makes the
prefill_32k shapes compile within per-device memory.  It doubles as the
oracle for the Pallas flash kernel (kernels/flash_attention.py).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(qpos, kpos, causal: bool, window) -> jnp.ndarray:
    """(…, q, k) boolean mask. window is 0 (off) or a traced/int scalar."""
    q = qpos[..., :, None]
    k = kpos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        ok = ok & (k <= q)
    ok = ok & jnp.where(window > 0, (q - k) < window, True)
    return ok


def dense_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0,
                    q_offset: int = 0) -> jnp.ndarray:
    """Reference attention materializing the score matrix.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) with H = KV * rep."""
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    rep = h // kv
    scale = hd ** -0.5
    qr = q.reshape(b, sq, kv, rep, hd).astype(jnp.float32)
    s = jnp.einsum("bqkrd,bskd->bkrqs", qr, k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(skv)
    m = _mask(qpos, kpos, causal, window)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrqs,bskd->bqkrd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0,
                    q_offset: int = 0, chunk_q: int = 1024,
                    chunk_kv: int = 1024) -> jnp.ndarray:
    """Online-softmax chunked attention (never materializes Sq x Skv).

    Block-sparsity (§Perf hillclimb #2): q chunks iterate in a *python*
    loop, so each chunk's kv scan statically covers only blocks inside the
    causal triangle — fully-masked future blocks are never built.  If
    ``window`` is a static python int > 0, past blocks outside the sliding
    window are statically skipped too (banded attention: O(S·W) instead of
    O(S²) — this is what makes gemma3's 5 local layers per global layer
    cheap at 32k)."""
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    rep = h // kv
    scale = hd ** -0.5
    cq = min(chunk_q, sq)
    ckv = min(chunk_kv, skv)
    # pad to multiples
    pq = (-sq) % cq
    pkv = (-skv) % ckv
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    nq, nkv = (sq + pq) // cq, (skv + pkv) // ckv
    qr = (qp.reshape(b, nq, cq, kv, rep, hd)
          .transpose(1, 0, 3, 4, 2, 5))          # (nq, B, KV, rep, cq, hd)
    kr = kp.reshape(b, nkv, ckv, kv, hd).transpose(1, 0, 3, 2, 4)
    vr = vp.reshape(b, nkv, ckv, kv, hd).transpose(1, 0, 3, 2, 4)
    static_window = isinstance(window, int) and window > 0

    def kv_block_fn(qb, qpos):
        def kv_block(carry, inp):
            ki, kb, vb = inp
            m_run, l_run, acc = carry
            kpos = ki * ckv + jnp.arange(ckv)
            s = jnp.einsum("bkrqd,bksd->bkrqs", qb,
                           kb.astype(jnp.float32)) * scale
            ok = _mask(qpos, kpos, causal, window) & (kpos < skv)[None, :]
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkrqs,bksd->bkrqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc), None
        return kv_block

    outs = []
    for qi in range(nq):  # static: enables causal/banded block skipping
        qb = qr[qi].astype(jnp.float32)
        qpos = q_offset + qi * cq + jnp.arange(cq)
        # static kv block range for this q chunk
        hi = nkv
        if causal and q_offset == 0:
            hi = min(nkv, ((qi + 1) * cq + ckv - 1) // ckv)
        lo = 0
        if static_window and causal and q_offset == 0:
            lo = max(0, (qi * cq - window) // ckv)
        init = (jnp.full((b, kv, rep, cq), NEG_INF, jnp.float32),
                jnp.zeros((b, kv, rep, cq), jnp.float32),
                jnp.zeros((b, kv, rep, cq, hd), jnp.float32))
        (m_run, l_run, acc), _ = jax.lax.scan(
            kv_block_fn(qb, qpos), init,
            (jnp.arange(lo, hi), kr[lo:hi], vr[lo:hi]))
        outs.append(acc / jnp.maximum(l_run, 1e-30)[..., None])

    out = jnp.stack(outs)                         # (nq, B, KV, rep, cq, hd)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * cq, h, hd)
    return out[:, :sq].astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, index: jnp.ndarray,
                     window: int = 0) -> jnp.ndarray:
    """Single-token attention against a (possibly sharded) KV cache.

    q: (B, 1, H, hd); caches: (B, S, KV, hd); index: (B,) per-slot
    positions (continuous batching: every slot has its own length)."""
    b, _, h, hd = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    rep = h // kv
    scale = hd ** -0.5
    qr = q.reshape(b, kv, rep, hd).astype(jnp.float32)
    logits = jnp.einsum("bkrd,bskd->bkrs", qr,
                        k_cache.astype(jnp.float32)) * scale
    kpos = jnp.arange(s)[None, :]
    idx = index[:, None]
    ok = (kpos <= idx) & jnp.where(window > 0, (idx - kpos) < window, True)
    logits = jnp.where(ok[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrs,bskd->bkrd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)
