"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

Dispatch is scatter/gather based (no (tokens x experts x capacity) one-hot
einsum): tokens are assigned a position inside their expert's capacity
buffer via a running count; overflow tokens are dropped (their residual
passes through), exactly like Switch/GShard capacity routing.

**Locality-grouped dispatch (§Perf hillclimb)**: under SPMD, a single
global scatter forces XLA to materialize and all-reduce the whole
(E, C, D) buffer across the data axis (TB-scale per step for dbrx).  We
instead split tokens into ``groups`` aligned with the data shards; each
group scatters into its own (E, C/g, D) slab via a vmapped local scatter,
so the only cross-device movement is the (group <-> expert) resharding in
front of the expert einsum — the canonical MoE all-to-all.  Experts shard
over the model axis (EP).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import init_dense
from repro.parallel import ctx


def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype):
    ks = jax.random.split(key, 4)
    return {
        "router": init_dense(ks[0], (d_model, n_experts), dtype),
        "w_gate": init_dense(ks[1], (n_experts, d_model, d_ff), dtype),
        "w_up": init_dense(ks[2], (n_experts, d_model, d_ff), dtype),
        "w_down": init_dense(ks[3], (n_experts, d_ff, d_model), dtype),
    }


def _dispatch_groups(t: int) -> int:
    """Token groups = product of the active batch mesh axes (1 off-mesh)."""
    mesh = ctx.current_mesh()
    if mesh is None:
        return 1
    g = 1
    for ax in ctx.batch_axes():
        g *= mesh.shape.get(ax, 1)
    while g > 1 and t % g != 0:
        g //= 2
    return max(g, 1)


def moe_layer(params: Dict, x: jnp.ndarray, top_k: int,
              capacity_factor: float = 1.25,
              aux_weight: float = 0.01,
              groups: Optional[int] = None) -> Tuple[jnp.ndarray,
                                                     jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    g = groups if groups is not None else _dispatch_groups(t)
    tg = t // g
    n_experts = params["router"].shape[1]
    capacity = int(max(1, round(tg * top_k / n_experts * capacity_factor)))

    xg = x.reshape(g, tg, d)
    xg = ctx.constrain(xg, "batch", None, None)
    logits = (xg.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))     # (g, tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)            # (g, tg, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(g, tg * top_k)                 # (g, T_g*K)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - 1                  # per-group count
    flat_pos = jnp.take_along_axis(pos, flat_e[..., None], 2)[..., 0]
    keep = flat_pos < capacity
    safe_pos = jnp.where(keep, flat_pos, 0)
    token_idx = jnp.repeat(jnp.arange(tg), top_k)         # shared per group

    def scatter_group(xg_, fe, sp, keep_):
        contrib = jnp.where(keep_[:, None], xg_[token_idx], 0)
        buf = jnp.zeros((n_experts, capacity, d), x.dtype)
        return buf.at[fe, sp].add(contrib)

    dispatched = jax.vmap(scatter_group)(xg, flat_e, safe_pos, keep)
    # E-major layout: (E@model, g@batch, C, D).  The expert einsums then
    # contract entirely locally (weights are E@model too); the only
    # cross-device movement is inside the scatter/gather — the MoE
    # all-to-all — instead of a whole-buffer reshard around the einsum.
    dispatched = jnp.swapaxes(dispatched, 0, 1)           # (E, g, C, D)
    dispatched = ctx.constrain(dispatched, "model", "batch", None, None)

    # Grouped expert FFN (SwiGLU): (E, g, C, D) x (E, D, F)
    gate = jax.nn.silu(jnp.einsum("egcd,edf->egcf", dispatched,
                                  params["w_gate"]))
    up = jnp.einsum("egcd,edf->egcf", dispatched, params["w_up"])
    expert_out = jnp.einsum("egcf,efd->egcd", gate * up, params["w_down"])
    expert_out = ctx.constrain(expert_out, "model", "batch", None, None)
    expert_out = jnp.swapaxes(expert_out, 0, 1)           # (g, E, C, D)

    def gather_group(eo, fe, sp, keep_, tp):
        gathered = eo[fe, sp]                             # (T_g*K, D)
        w = (tp.reshape(-1) * keep_).astype(x.dtype)
        return jax.ops.segment_sum(gathered * w[:, None], token_idx,
                                   num_segments=tg)

    combined = jax.vmap(gather_group)(expert_out, flat_e, safe_pos, keep,
                                      top_p)
    combined = ctx.constrain(combined, "batch", None, None)

    # Load-balancing auxiliary loss (Switch-style), global over all groups.
    me = probs.reshape(t, n_experts).mean(0)
    ce = jax.nn.one_hot(top_e.reshape(t, top_k)[:, 0], n_experts).mean(0)
    aux = aux_weight * n_experts * jnp.sum(me * ce)
    return combined.reshape(b, s, d), aux.astype(jnp.float32)
