"""Mamba2 (state-space duality / SSD) blocks.

``ssd_chunked`` implements the chunked SSD algorithm of arXiv:2405.21060:
quadratic attention-like computation inside chunks, a linear recurrence on
chunk states across chunks.  ``ssd_reference`` is the naive sequential
recurrence used as the correctness oracle (and the Pallas kernel's ref).

Shapes: x (B, L, H, P)   dt (B, L, H)   A (H,)   B, C (B, L, G, N), G=1.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense, rms_norm


def ssd_reference(x, dt, a, b, c, d_skip=None):
    """Sequential SSD recurrence: S_t = S_{t-1} exp(dt_t A) + dt_t B_t x_t."""
    bs, l, h, p = x.shape
    n = b.shape[-1]
    g = b.shape[2]
    rep = h // g
    bh = jnp.repeat(b, rep, axis=2)  # (B, L, H, N)
    ch = jnp.repeat(c, rep, axis=2)

    def step(s, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dtt * a)[..., None, None]        # (B,H,1,1)
        s = s * decay + (dtt[..., None] * bt)[..., :, None] * xt[..., None, :]
        y = jnp.einsum("bhn,bhnp->bhp", ct, s)
        return s, y

    s0 = jnp.zeros((bs, h, n, p), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          bh.transpose(1, 0, 2, 3).astype(jnp.float32),
          ch.transpose(1, 0, 2, 3).astype(jnp.float32))
    _, ys = jax.lax.scan(step, s0, xs)
    y = ys.transpose(1, 0, 2, 3)
    if d_skip is not None:
        y = y + d_skip[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype)


def ssd_chunked(x, dt, a, b, c, d_skip=None, chunk: int = 256,
                return_final=False):
    """Chunked SSD (the paper's hardware-efficient dual form)."""
    bs, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lp = l + pad
    nc = lp // q
    xc = x.reshape(bs, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(bs, nc, q, h).astype(jnp.float32)
    bc = b.reshape(bs, nc, q, g, n).astype(jnp.float32)
    cc = c.reshape(bs, nc, q, g, n).astype(jnp.float32)
    rep = h // g
    bhc = jnp.repeat(bc, rep, axis=3)                   # (B,nc,Q,H,N)
    chc = jnp.repeat(cc, rep, axis=3)

    adt = dtc * a  # (B, nc, Q, H), negative
    cum = jnp.cumsum(adt, axis=2)

    # ---- intra-chunk (quadratic within chunk) ----------------------------
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    ii = jnp.arange(q)
    causal = (ii[:, None] >= ii[None, :])
    decay = jnp.exp(jnp.where(causal[None, None, :, :, None], seg, -jnp.inf))
    scores = jnp.einsum("bcihn,bcjhn->bcijh", chc, bhc)
    att = scores * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xc)

    # ---- chunk states -----------------------------------------------------
    tail = jnp.exp(cum[:, :, -1:, :] - cum)              # (B,nc,Q,H)
    weighted = (tail * dtc)[..., None] * bhc             # (B,nc,Q,H,N)
    states = jnp.einsum("bcqhn,bcqhp->bchnp", weighted, xc)

    # ---- inter-chunk recurrence -------------------------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B,nc,H)

    def chunk_step(s, inp):
        st, dec = inp                                    # (B,H,N,P), (B,H)
        s_prev = s
        s = s * dec[..., None, None] + st
        return s, s_prev

    s0 = jnp.zeros((bs, h, n, p), jnp.float32)
    s_final, s_prevs = jax.lax.scan(
        chunk_step, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)           # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp", chc * jnp.exp(cum)[..., None],
                         s_prevs)
    y = (y_intra + y_inter).reshape(bs, lp, h, p)[:, :l]
    if d_skip is not None:
        y = y + d_skip[None, None, :, None] * x.reshape(bs, lp, h, p)[:, :l]
    y = y.astype(jnp.float32)
    if return_final:
        return y, s_final
    return y


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------
def init_mamba_block(key, cfg, dtype) -> Dict:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h, k = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    conv_dim = di + 2 * g * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": init_dense(ks[0], (d, 2 * di + 2 * g * n + h), dtype),
        "conv_w": init_dense(ks[1], (k, conv_dim), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": init_dense(ks[3], (di, d), dtype),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray = None):
    """Depthwise causal conv along seq.  xbc: (B, L, C); w: (K, C)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else pad
    return jax.nn.silu(out + b), new_state


def _split_proj(cfg, proj):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:2 * di + 2 * g * n]
    dt = proj[..., 2 * di + 2 * g * n:]
    return z, xbc, dt


def mamba_block(params: Dict, x: jnp.ndarray, cfg, return_state=False):
    """Training/prefill Mamba2 block.  x: (B, L, D) -> (B, L, D)."""
    bs, l, d = x.shape
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim
    proj = x @ params["in_proj"]
    z, xbc_raw, dt_raw = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(xbc_raw, params["conv_w"],
                                   params["conv_b"])
    xs = xbc[..., :di].reshape(bs, l, h, p)
    bmat = xbc[..., di:di + g * n].reshape(bs, l, g, n)
    cmat = xbc[..., di + g * n:].reshape(bs, l, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    y, s_final = ssd_chunked(xs, dt, a, bmat, cmat, params["d_skip"],
                             chunk=cfg.ssm_chunk, return_final=True)
    y = y.reshape(bs, l, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if return_state:
        return out, {"conv": conv_state, "ssm": s_final}
    return out


def init_mamba_state(cfg, batch: int, dtype):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * g * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, n, cfg.ssm_head_dim), jnp.float32),
    }


def mamba_decode_step(params: Dict, x: jnp.ndarray, state: Dict,
                      cfg) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode.  x: (B, 1, D)."""
    bs = x.shape[0]
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim
    proj = x @ params["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                   state["conv"])
    xs = xbc[..., :di].reshape(bs, h, p)
    bmat = xbc[..., di:di + g * n].reshape(bs, g, n)
    cmat = xbc[..., di + g * n:].reshape(bs, g, n)
    rep = h // g
    bh = jnp.repeat(bmat, rep, axis=1).astype(jnp.float32)
    ch = jnp.repeat(cmat, rep, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,H)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a)[..., None, None]
    s = state["ssm"] * decay + \
        (dt[..., None] * bh)[..., :, None] * xs.astype(jnp.float32)[..., None, :]
    y = jnp.einsum("bhn,bhnp->bhp", ch, s)
    y = y + params["d_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bs, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["out_proj"], {"conv": conv_state, "ssm": s}
