"""Model and shape configuration dataclasses.

One :class:`ModelConfig` per architecture (see ``repro.configs``), one
:class:`ShapeConfig` per assigned input-shape cell.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # attention pattern
    sliding_window: int = 0     # 0 = full attention everywhere
    global_every: int = 0       # gemma3: every Nth layer is global
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # hybrid (zamba2): one *shared* attention block applied every N layers
    attn_every: int = 0
    # modality frontend (stub): precomputed patch/frame embeddings
    frontend: str = ""          # "" | "vision" | "audio"
    frontend_prefix_len: int = 0
    frontend_dim: int = 0       # raw embedding dim before projection
    # numerics / execution
    param_dtype: str = "float32"
    act_dtype: str = "float32"
    remat: bool = False
    remat_policy: str = "full"   # full | dots (save matmul outputs)
    sharding_profile: str = "2d"  # 2d (FSDP x TP) | dp (pure DP/FSDP) | sp
    sharding_profile_serve: str = ""  # override for prefill/decode ("" = same)
    attn_chunk_q: int = 1024
    attn_chunk_kv: int = 1024
    use_flash: bool = True      # chunked (flash) attention vs dense scores
    train_accum_steps: int = 1  # microbatching (keeps big models in HBM)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def ssm_groups(self) -> int:
        return 1

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (see DESIGN.md §4)."""
        return (self.family in ("ssm", "hybrid")
                or (self.sliding_window > 0 and self.global_every > 0))

    def n_params(self) -> float:
        """Total parameter count (embedding included)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        if self.n_experts:
            mlp = self.n_experts * 3 * d * f + d * self.n_experts
        else:
            mlp = 3 * d * f
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, g, n, h = (self.d_inner, self.ssm_groups, self.ssm_state,
                           self.ssm_heads)
            proj = d * (2 * di + 2 * g * n + h) + di * d
            conv = self.ssm_conv * (di + 2 * g * n)
            ssm = proj + conv + 3 * h + di
        per_layer = 2 * d  # norms
        if self.family == "ssm":
            per_layer += ssm
        elif self.family == "hybrid":
            per_layer += ssm
            n_attn = self.n_layers // max(self.attn_every, 1)
            emb += (attn + mlp + 2 * d)  # one shared block
            return emb + self.n_layers * per_layer + 2 * d
        else:
            per_layer += attn + mlp
        return emb + self.n_layers * per_layer + 2 * d

    def n_active_params(self) -> float:
        """Active parameters per token (MoE: only top_k experts)."""
        if not self.n_experts:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        dense_equiv = dataclasses.replace(self, n_experts=0, top_k=0)
        base = dense_equiv.n_params() - self.n_layers * 3 * d * f
        return base + self.n_layers * (self.top_k * 3 * d * f
                                       + d * self.n_experts)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                   # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """A reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128 if not cfg.n_experts else 32,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_chunk=8,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
        global_every=min(cfg.global_every, 2) if cfg.global_every else 0,
        attn_every=2 if cfg.attn_every else 0,
        frontend_prefix_len=min(cfg.frontend_prefix_len, 4)
        if cfg.frontend else 0,
        frontend_dim=32 if cfg.frontend else 0,
        attn_chunk_q=8, attn_chunk_kv=8,
        param_dtype="float32", act_dtype="float32", remat=False,
    )
