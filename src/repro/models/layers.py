"""Shared building-block layers (pure functional JAX)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)) \
        .astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """Rotary position embedding.

    x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta), jnp.float32)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (.., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    gate = jax.nn.silu(x @ w_gate)
    return (gate * (x @ w_up)) @ w_down


def init_dense(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss_weight: float = 0.0) -> jnp.ndarray:
    """Token-level CE in f32, with optional z-loss stabilizer.

    (A vocab-chunked logsumexp was tried for big-vocab residency and
    REFUTED: the chunk transpose breaks the model-axis vocab sharding and
    replicates the logits — see experiments/perf_log.md iteration 6.)"""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    label_logits = jnp.take_along_axis(
        logits, labels[..., None], axis=-1)[..., 0]
    loss = logz - label_logits
    if z_loss_weight:
        loss = loss + z_loss_weight * jnp.square(logz)
    return jnp.mean(loss)
