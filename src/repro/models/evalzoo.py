"""The paper's evaluation networks (Table 4), reimplemented in JAX.

Habitat's accuracy is evaluated on ResNet-50, Inception v3, the
Transformer, GNMT, and DCGAN.  We reproduce each at configurable scale
(full configs match the papers; benchmarks default to reduced widths so the
tracer's jaxpr walk stays fast on CPU, which does not change the *mix* of
kernel-varying vs kernel-alike ops).

Each entry returns ``(train_step_fn, params, batch)`` where
``train_step_fn(params, batch)`` computes a scalar loss — the exact callable
the paper's tracker wraps (Listing 1's ``run_my_training_iteration``).
Optimizer updates are applied by the caller (SGD for the vision models,
Adam for the rest, per Sec. 5.1).
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import init_dense


def _conv(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def _conv_t(x, w, stride=2):
    """Transposed conv (DCGAN generator upsampling)."""
    return jax.lax.conv_transpose(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        transpose_kernel=True)


def _bn(x, scale, bias):
    mean = x.mean((0, 2, 3), keepdims=True)
    var = x.var((0, 2, 3), keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + 1e-5)
    return xn * scale[None, :, None, None] + bias[None, :, None, None]


# ---------------------------------------------------------------------------
# ResNet (bottleneck blocks; depth 50 at scale=1)
# ---------------------------------------------------------------------------
def make_resnet(key, batch: int = 32, image: int = 224, width: int = 64,
                blocks=(3, 4, 6, 3), classes: int = 1000):
    stages = len(blocks)
    params = {"stem": init_dense(key, (width, 3, 7, 7), jnp.float32)}
    k = key
    for s in range(stages):
        cin = width * (2 ** max(s - 1, 0)) if s else width
        cout = width * (2 ** s)
        for b in range(blocks[s]):
            k = jax.random.fold_in(k, s * 10 + b)
            c_in = cin if b == 0 else cout
            params[f"s{s}b{b}"] = {
                "w1": init_dense(jax.random.fold_in(k, 1),
                                 (cout, c_in, 1, 1), jnp.float32),
                "w2": init_dense(jax.random.fold_in(k, 2),
                                 (cout, cout, 3, 3), jnp.float32),
                "w3": init_dense(jax.random.fold_in(k, 3),
                                 (cout, cout, 1, 1), jnp.float32),
                "proj": init_dense(jax.random.fold_in(k, 4),
                                   (cout, c_in, 1, 1), jnp.float32),
                "g1": jnp.ones((cout,)), "b1": jnp.zeros((cout,)),
                "g2": jnp.ones((cout,)), "b2": jnp.zeros((cout,)),
                "g3": jnp.ones((cout,)), "b3": jnp.zeros((cout,)),
            }
    params["head"] = init_dense(jax.random.fold_in(key, 99),
                                (width * 2 ** (stages - 1), classes),
                                jnp.float32)

    def apply(params, x):
        h = jax.nn.relu(_conv(x, params["stem"], stride=2))
        for s in range(stages):
            for b in range(blocks[s]):
                p = params[f"s{s}b{b}"]
                stride = 2 if (b == 0 and s > 0) else 1
                r = jax.nn.relu(_bn(_conv(h, p["w1"], stride), p["g1"],
                                    p["b1"]))
                r = jax.nn.relu(_bn(_conv(r, p["w2"]), p["g2"], p["b2"]))
                r = _bn(_conv(r, p["w3"]), p["g3"], p["b3"])
                sc = _conv(h, p["proj"], stride)
                h = jax.nn.relu(r + sc)
        pooled = h.mean((2, 3))
        return pooled @ params["head"]

    def step(params, batch_):
        logits = apply(params, batch_["x"])
        onehot = jax.nn.one_hot(batch_["y"], logits.shape[-1])
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    batch_ = {"x": jnp.ones((batch, 3, image, image), jnp.float32),
              "y": jnp.zeros((batch,), jnp.int32)}
    return step, params, batch_


# ---------------------------------------------------------------------------
# Inception-style (parallel mixed branches -> large graph fanout)
# ---------------------------------------------------------------------------
def make_inception(key, batch: int = 32, image: int = 224, width: int = 64,
                   n_blocks: int = 8, classes: int = 1000):
    params = {"stem": init_dense(key, (width, 3, 3, 3), jnp.float32)}
    c = width
    for i in range(n_blocks):
        k = jax.random.fold_in(key, i)
        params[f"mix{i}"] = {
            "b1": init_dense(jax.random.fold_in(k, 1), (c, c, 1, 1),
                             jnp.float32),
            "b3a": init_dense(jax.random.fold_in(k, 2), (c, c, 1, 1),
                              jnp.float32),
            "b3b": init_dense(jax.random.fold_in(k, 3), (c, c, 3, 3),
                              jnp.float32),
            "b5a": init_dense(jax.random.fold_in(k, 4), (c, c, 1, 1),
                              jnp.float32),
            "b5b": init_dense(jax.random.fold_in(k, 5), (c, c, 5, 5),
                              jnp.float32),
            "bp": init_dense(jax.random.fold_in(k, 6), (c, 3 * c, 1, 1),
                             jnp.float32),
        }
    params["head"] = init_dense(jax.random.fold_in(key, 99), (c, classes),
                                jnp.float32)

    def apply(params, x):
        h = jax.nn.relu(_conv(x, params["stem"], stride=2))
        for i in range(n_blocks):
            p = params[f"mix{i}"]
            br1 = jax.nn.relu(_conv(h, p["b1"]))
            br3 = jax.nn.relu(_conv(jax.nn.relu(_conv(h, p["b3a"])),
                                    p["b3b"]))
            br5 = jax.nn.relu(_conv(jax.nn.relu(_conv(h, p["b5a"])),
                                    p["b5b"]))
            h = jax.nn.relu(_conv(jnp.concatenate([br1, br3, br5], 1),
                                  p["bp"]))
        return h.mean((2, 3)) @ params["head"]

    def step(params, batch_):
        logits = apply(params, batch_["x"])
        onehot = jax.nn.one_hot(batch_["y"], logits.shape[-1])
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    batch_ = {"x": jnp.ones((batch, 3, image, image), jnp.float32),
              "y": jnp.zeros((batch,), jnp.int32)}
    return step, params, batch_


# ---------------------------------------------------------------------------
# DCGAN (generator + discriminator adversarial step)
# ---------------------------------------------------------------------------
def make_dcgan(key, batch: int = 128, image: int = 64, width: int = 64,
               z_dim: int = 100):
    kg = jax.random.fold_in(key, 0)
    kd = jax.random.fold_in(key, 1)
    g = {
        "fc": init_dense(kg, (z_dim, width * 4 * 4 * 4), jnp.float32),
        "c1": init_dense(jax.random.fold_in(kg, 1),
                         (width * 4, width * 2, 4, 4), jnp.float32),
        "c2": init_dense(jax.random.fold_in(kg, 2),
                         (width * 2, width, 4, 4), jnp.float32),
        "c3": init_dense(jax.random.fold_in(kg, 3), (width, 3, 4, 4),
                         jnp.float32),
    }
    d = {
        "c1": init_dense(kd, (width, 3, 4, 4), jnp.float32),
        "c2": init_dense(jax.random.fold_in(kd, 1),
                         (width * 2, width, 4, 4), jnp.float32),
        "c3": init_dense(jax.random.fold_in(kd, 2),
                         (width * 4, width * 2, 4, 4), jnp.float32),
        "fc": init_dense(jax.random.fold_in(kd, 3),
                         (width * 4, 1), jnp.float32),
    }
    params = {"g": g, "d": d}

    def generator(g, z):
        h = (z @ g["fc"]).reshape(-1, g["c1"].shape[0], 4, 4)
        h = jax.nn.relu(_conv_t(h, g["c1"]))
        h = jax.nn.relu(_conv_t(h, g["c2"]))
        return jnp.tanh(_conv_t(h, g["c3"]))

    def discriminator(d, x):
        h = jax.nn.leaky_relu(_conv(x, d["c1"], 2), 0.2)
        h = jax.nn.leaky_relu(_conv(h, d["c2"], 2), 0.2)
        h = jax.nn.leaky_relu(_conv(h, d["c3"], 2), 0.2)
        return h.mean((2, 3)) @ d["fc"]

    def step(params, batch_):
        fake = generator(params["g"], batch_["z"])
        d_fake = discriminator(params["d"], fake)
        d_real = discriminator(params["d"], batch_["x"])
        d_loss = jnp.mean(jax.nn.softplus(-d_real)) + \
            jnp.mean(jax.nn.softplus(d_fake))
        g_loss = jnp.mean(jax.nn.softplus(-d_fake))
        return d_loss + g_loss

    batch_ = {"x": jnp.ones((batch, 3, 32, 32), jnp.float32),
              "z": jnp.ones((batch, z_dim), jnp.float32)}
    return step, params, batch_


# ---------------------------------------------------------------------------
# GNMT (LSTM encoder-decoder with attention)
# ---------------------------------------------------------------------------
def _lstm_scan(w, h0, c0, xs):
    """xs: (S, B, I); w: (I+H, 4H)."""
    hidden = h0.shape[-1]

    def cell(carry, xt):
        h, c = carry
        z = jnp.concatenate([xt, h], -1) @ w
        i, f, g, o = jnp.split(z, 4, -1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    (_, _), hs = jax.lax.scan(cell, (h0, c0), xs)
    return hs


def make_gnmt(key, batch: int = 64, seq: int = 50, hidden: int = 512,
              vocab: int = 32000, layers: int = 4):
    ks = jax.random.split(key, 2 * layers + 4)
    params = {
        "src_embed": init_dense(ks[0], (vocab, hidden), jnp.float32,
                                scale=0.02),
        "tgt_embed": init_dense(ks[1], (vocab, hidden), jnp.float32,
                                scale=0.02),
        "attn": init_dense(ks[2], (hidden, hidden), jnp.float32),
        "head": init_dense(ks[3], (2 * hidden, vocab), jnp.float32),
    }
    for i in range(layers):
        params[f"enc{i}"] = init_dense(ks[4 + i], (2 * hidden, 4 * hidden),
                                       jnp.float32)
        params[f"dec{i}"] = init_dense(ks[4 + layers + i],
                                       (2 * hidden, 4 * hidden), jnp.float32)

    def step(params, batch_):
        src = params["src_embed"][batch_["src"]].transpose(1, 0, 2)
        tgt = params["tgt_embed"][batch_["tgt"]].transpose(1, 0, 2)
        b = src.shape[1]
        h0 = jnp.zeros((b, hidden))
        hs = src
        for i in range(layers):
            hs = _lstm_scan(params[f"enc{i}"], h0, h0, hs)
        ds = tgt
        for i in range(layers):
            ds = _lstm_scan(params[f"dec{i}"], h0, h0, ds)
        # Luong attention: decoder states attend over encoder states.
        scores = jnp.einsum("sbh,tbh->bst", hs @ params["attn"], ds)
        ctx = jnp.einsum("bst,sbh->tbh", jax.nn.softmax(scores, 1), hs)
        feat = jnp.concatenate([ds, ctx], -1)
        logits = feat @ params["head"]
        onehot = jax.nn.one_hot(batch_["tgt"].transpose(1, 0),
                                logits.shape[-1])
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    batch_ = {"src": jnp.ones((batch, seq), jnp.int32),
              "tgt": jnp.ones((batch, seq), jnp.int32)}
    return step, params, batch_


# ---------------------------------------------------------------------------
# Transformer (the paper uses the original encoder-decoder; we use the
# decoder-only equivalent from our model substrate at reduced width)
# ---------------------------------------------------------------------------
def make_transformer(key, batch: int = 32, seq: int = 128, d_model: int = 512,
                     n_layers: int = 6, vocab: int = 32000):
    from repro.models.config import ModelConfig
    from repro.models import transformer as tfm
    cfg = ModelConfig(
        name="paper-transformer", family="dense", n_layers=n_layers,
        d_model=d_model, n_heads=8, n_kv_heads=8, d_ff=4 * d_model,
        vocab_size=vocab, use_flash=False)
    params = tfm.init_params(cfg, key)

    def step(params, batch_):
        loss, _ = tfm.loss_fn(params, cfg, batch_)
        return loss

    tokens = jnp.ones((batch, seq), jnp.int32)
    return step, params, {"tokens": tokens, "labels": tokens}


ZOO: Dict[str, Callable] = {
    "resnet50": make_resnet,
    "inception_v3": make_inception,
    "dcgan": make_dcgan,
    "gnmt": make_gnmt,
    "transformer": make_transformer,
}


def make_train_iteration(name: str, key=None, grad: bool = True, **kw):
    """Return (iteration_fn, params, batch): fwd+bwd, the paper's unit."""
    key = key if key is not None else jax.random.PRNGKey(0)
    step, params, batch = ZOO[name](key, **kw)
    if not grad:
        return step, params, batch

    def iteration(params, batch_):
        loss, grads = jax.value_and_grad(step)(params, batch_)
        # SGD-style update included: the paper's "iteration" covers the
        # weight update too (Sec. 2.1).
        new = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
        return loss, new

    return iteration, params, batch
