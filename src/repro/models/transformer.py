"""Unified decoder model covering all assigned architecture families.

One functional model with ``init_params`` / ``forward`` / ``loss_fn`` /
``prefill`` / ``decode_step``, driven entirely by :class:`ModelConfig`:

  * dense / moe / vlm / audio : pre-norm attention + (SwiGLU | MoE) blocks,
    stacked with ``lax.scan`` (per-layer window sizes ride along as scan xs,
    which is how gemma3's 5:1 local:global pattern compiles to ONE block).
  * ssm    : Mamba2 (SSD) blocks.
  * hybrid : zamba2-style supercells — ``attn_every`` Mamba2 layers followed
    by one application of a *weight-shared* attention+MLP block.

VLM / audio frontends are stubs per the assignment: ``prefix_embeds``
(precomputed patch/frame embeddings) are linearly projected and prepended.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (cross_entropy, init_dense, rms_norm,
                                 apply_rope, swiglu)
from repro.parallel import ctx

#: batch axes for activation sharding hints (no-ops without a mesh)
_BATCH = ("pod", "data")


def _shard_act(x):
    """Keep (B, S, D) activations batch- (and, under the sequence-parallel
    profile, sequence-) sharded through scans."""
    return ctx.constrain(x, "batch", "seq", None)


def _remat(fn, cfg):
    """Wrap a scan body with the configured activation-checkpoint policy."""
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------
def _init_attn_block(key, cfg: ModelConfig, dtype) -> Dict:
    d, h, kv, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim)
    ks = jax.random.split(key, 6)
    p = {
        "ln1": jnp.ones((d,), dtype),
        "wq": init_dense(ks[0], (d, h * hd), dtype),
        "wk": init_dense(ks[1], (d, kv * hd), dtype),
        "wv": init_dense(ks[2], (d, kv * hd), dtype),
        "wo": init_dense(ks[3], (h * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _init_mlp_block(key, cfg: ModelConfig, dtype) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    p = {"ln2": jnp.ones((d,), dtype)}
    if cfg.n_experts:
        p["moe"] = moe_mod.init_moe(key, d, f, cfg.n_experts, dtype)
    else:
        ks = jax.random.split(key, 3)
        p["w_gate"] = init_dense(ks[0], (d, f), dtype)
        p["w_up"] = init_dense(ks[1], (d, f), dtype)
        p["w_down"] = init_dense(ks[2], (f, d), dtype)
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key) -> Dict:
    dtype = _dtype(cfg)
    keys = jax.random.split(key, cfg.n_layers + 8)
    params: Dict[str, Any] = {
        "embed": init_dense(keys[-1], (cfg.vocab_size, cfg.d_model), dtype,
                            scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(keys[-2],
                                       (cfg.d_model, cfg.vocab_size), dtype)
    if cfg.frontend:
        params["frontend_proj"] = init_dense(
            keys[-3], (cfg.frontend_dim, cfg.d_model), dtype)

    if cfg.family == "ssm":
        blocks = [dict(ln=jnp.ones((cfg.d_model,), dtype),
                       **{"mamba": ssm_mod.init_mamba_block(keys[i], cfg,
                                                            dtype)})
                  for i in range(cfg.n_layers)]
        params["layers"] = _stack(blocks)
    elif cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        blocks = [dict(ln=jnp.ones((cfg.d_model,), dtype),
                       **{"mamba": ssm_mod.init_mamba_block(keys[i], cfg,
                                                            dtype)})
                  for i in range(cfg.n_layers)]
        grouped = [_stack(blocks[g * cfg.attn_every:(g + 1) * cfg.attn_every])
                   for g in range(n_groups)]
        params["layers"] = _stack(grouped)
        shared = _init_attn_block(keys[-4], cfg, dtype)
        shared.update(_init_mlp_block(keys[-5], cfg, dtype))
        params["shared_attn"] = shared
    else:
        blocks = []
        for i in range(cfg.n_layers):
            blk = _init_attn_block(keys[i], cfg, dtype)
            blk.update(_init_mlp_block(
                jax.random.fold_in(keys[i], 1), cfg, dtype))
            blocks.append(blk)
        params["layers"] = _stack(blocks)
    return params


def layer_windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer attention windows: 0 = full attention."""
    if cfg.sliding_window and cfg.global_every:
        w = np.full((cfg.n_layers,), cfg.sliding_window, np.int32)
        w[cfg.global_every - 1::cfg.global_every] = 0  # every Nth is global
        return w
    if cfg.sliding_window:
        return np.full((cfg.n_layers,), cfg.sliding_window, np.int32)
    return np.zeros((cfg.n_layers,), np.int32)


# ---------------------------------------------------------------------------
# Blocks (training / prefill form)
# ---------------------------------------------------------------------------
def _attention(blk: Dict, x: jnp.ndarray, cfg: ModelConfig, window,
               positions: jnp.ndarray) -> jnp.ndarray:
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    hn = rms_norm(x, blk["ln1"], cfg.norm_eps)
    q = (hn @ blk["wq"]).reshape(b, s, h, hd)
    k = (hn @ blk["wk"]).reshape(b, s, kv, hd)
    v = (hn @ blk["wv"]).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, blk["q_norm"], cfg.norm_eps)
        k = rms_norm(k, blk["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.use_flash and s > cfg.attn_chunk_q:
        o = attn_mod.flash_attention(q, k, v, causal=True, window=window,
                                     chunk_q=cfg.attn_chunk_q,
                                     chunk_kv=cfg.attn_chunk_kv)
    else:
        o = attn_mod.dense_attention(q, k, v, causal=True, window=window)
    return o.reshape(b, s, h * hd) @ blk["wo"], (k, v)


def _mlp(blk: Dict, x: jnp.ndarray, cfg: ModelConfig):
    hn = rms_norm(x, blk["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        out, aux = moe_mod.moe_layer(blk["moe"], hn, cfg.top_k,
                                     cfg.capacity_factor,
                                     cfg.router_aux_weight)
        return out, aux
    return swiglu(hn, blk["w_gate"], blk["w_up"], blk["w_down"]), 0.0


def _attn_mlp_block(blk: Dict, x: jnp.ndarray, cfg: ModelConfig, window,
                    positions):
    a, kv_pair = _attention(blk, x, cfg, window, positions)
    x = _shard_act(x + a)
    m, aux = _mlp(blk, x, cfg)
    return _shard_act(x + m), aux, kv_pair


def _mamba_layer(layer: Dict, x: jnp.ndarray, cfg: ModelConfig,
                 return_state=False):
    hn = rms_norm(x, layer["ln"], cfg.norm_eps)
    if return_state:
        out, st = ssm_mod.mamba_block(layer["mamba"], hn, cfg,
                                      return_state=True)
        return _shard_act(x + out), st
    return _shard_act(x + ssm_mod.mamba_block(layer["mamba"], hn, cfg))


# ---------------------------------------------------------------------------
# Forward (training)
# ---------------------------------------------------------------------------
def _embed_inputs(params, cfg, tokens, prefix_embeds):
    x = params["embed"][tokens] * jnp.asarray(
        np.sqrt(cfg.d_model), _dtype(cfg))
    if cfg.frontend and prefix_embeds is not None:
        pre = prefix_embeds.astype(_dtype(cfg)) @ params["frontend_proj"]
        x = jnp.concatenate([pre, x], axis=1)
    return _shard_act(x)


def forward(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
            prefix_embeds: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits over the token positions, aux losses)."""
    x = _embed_inputs(params, cfg, tokens, prefix_embeds)
    b, s, d = x.shape
    positions = jnp.arange(s)[None, :].repeat(b, 0)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family == "ssm":
        def body(carry, layer):
            return _mamba_layer(layer, carry, cfg), None
        body = _remat(body, cfg)
        x, _ = jax.lax.scan(body, x, params["layers"])
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group(carry, layers):
            def inner(c, layer):
                return _mamba_layer(layer, c, cfg), None
            # nested remat: the SSD intra-chunk tensors of all attn_every
            # inner layers would otherwise be live at once in the group's
            # backward recompute
            inner = jax.checkpoint(inner) if cfg.remat else inner
            h, _ = jax.lax.scan(inner, carry, layers)
            h, aux, _ = _attn_mlp_block(shared, h, cfg, 0, positions)
            return h, aux
        group = _remat(group, cfg)
        x, auxs = jax.lax.scan(group, x, params["layers"])
        aux_total = aux_total + jnp.sum(auxs)
    else:
        windows = jnp.asarray(layer_windows(cfg))

        def body(carry, inp):
            layer, window = inp
            h, aux, _ = _attn_mlp_block(layer, carry, cfg, window, positions)
            return h, aux
        body = _remat(body, cfg)
        x, auxs = jax.lax.scan(body, x, (params["layers"], windows))
        aux_total = aux_total + jnp.sum(auxs)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.frontend and prefix_embeds is not None:
        x = x[:, prefix_embeds.shape[1]:]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = ctx.constrain(x @ head, "batch", None, "model")
    return logits, aux_total


def loss_fn(params: Dict, cfg: ModelConfig, batch: Dict) -> Tuple[jnp.ndarray,
                                                                  Dict]:
    logits, aux = forward(params, cfg, batch["tokens"],
                          batch.get("prefix_embeds"))
    ce = cross_entropy(logits, batch["labels"])
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with caches
# ---------------------------------------------------------------------------
def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    dtype = _dtype(cfg)
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    # per-slot positions: continuous batching keeps a length per sequence
    state: Dict[str, Any] = {"index": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "ssm":
        st = ssm_mod.init_mamba_state(cfg, batch, dtype)
        state["ssm_layers"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), st)
    elif cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        st = ssm_mod.init_mamba_state(cfg, batch, dtype)
        state["ssm_layers"] = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x, (n_groups, cfg.attn_every) + x.shape), st)
        state["k"] = jnp.zeros((n_groups, batch, max_seq, kv, hd), dtype)
        state["v"] = jnp.zeros((n_groups, batch, max_seq, kv, hd), dtype)
    else:
        state["k"] = jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dtype)
        state["v"] = jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dtype)
    return state


def prefill(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
            max_seq: int, prefix_embeds: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, Dict]:
    """Run the prompt, returning (last-position logits, decode state)."""
    x = _embed_inputs(params, cfg, tokens, prefix_embeds)
    b, s, d = x.shape
    positions = jnp.arange(s)[None, :].repeat(b, 0)
    state = init_decode_state(cfg, b, max_seq)
    state["index"] = jnp.full((b,), s, jnp.int32)

    def pad_kv(k):
        return jnp.pad(k, ((0, 0), (0, max_seq - s), (0, 0), (0, 0)))

    if cfg.family == "ssm":
        def body(carry, layer):
            h, st = _mamba_layer(layer, carry, cfg, return_state=True)
            return h, st
        x, states = jax.lax.scan(body, x, params["layers"])
        state["ssm_layers"] = states
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group(carry, layers):
            def inner(c, layer):
                return _mamba_layer(layer, c, cfg, return_state=True)
            h, sts = jax.lax.scan(inner, carry, layers)
            h, _, (k, v) = _attn_mlp_block(shared, h, cfg, 0, positions)
            return h, (sts, pad_kv(k), pad_kv(v))
        x, (sts, ks, vs) = jax.lax.scan(group, x, params["layers"])
        state["ssm_layers"] = sts
        state["k"], state["v"] = ks, vs
    else:
        windows = jnp.asarray(layer_windows(cfg))

        def body(carry, inp):
            layer, window = inp
            h, _, (k, v) = _attn_mlp_block(layer, carry, cfg, window,
                                           positions)
            return h, (pad_kv(k), pad_kv(v))
        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], windows))
        state["k"], state["v"] = ks, vs

    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, state


def _decode_attention_block(blk: Dict, x: jnp.ndarray, cfg: ModelConfig,
                            window, index, k_cache, v_cache):
    """x: (B, 1, D); index: (B,) per-slot positions."""
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    hn = rms_norm(x, blk["ln1"], cfg.norm_eps)
    q = (hn @ blk["wq"]).reshape(b, 1, h, hd)
    k = (hn @ blk["wk"]).reshape(b, 1, kv, hd)
    v = (hn @ blk["wv"]).reshape(b, 1, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, blk["q_norm"], cfg.norm_eps)
        k = rms_norm(k, blk["k_norm"], cfg.norm_eps)
    pos = index[:, None]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    # per-slot cache insertion (each slot writes at its own position)
    upd = jax.vmap(
        lambda cb, kb, i: jax.lax.dynamic_update_slice_in_dim(cb, kb, i, 0))
    k_cache = upd(k_cache, k, index)
    v_cache = upd(v_cache, v, index)
    o = attn_mod.decode_attention(q, k_cache, v_cache, index, window)
    out = o.reshape(b, 1, h * hd) @ blk["wo"]
    m, _ = _mlp(blk, x + out, cfg)
    return x + out + m, k_cache, v_cache


def decode_step(params: Dict, cfg: ModelConfig, token: jnp.ndarray,
                state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One decode step.  token: (B, 1) int32 -> (logits (B,1,V), state)."""
    x = params["embed"][token] * jnp.asarray(
        np.sqrt(cfg.d_model), _dtype(cfg))
    index = state["index"]
    new_state = dict(state)

    if cfg.family == "ssm":
        def body(carry, inp):
            layer, st = inp
            hn = rms_norm(carry, layer["ln"], cfg.norm_eps)
            out, st2 = ssm_mod.mamba_decode_step(layer["mamba"], hn, st, cfg)
            return carry + out, st2
        x, sts = jax.lax.scan(body, x, (params["layers"],
                                        state["ssm_layers"]))
        new_state["ssm_layers"] = sts
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group(carry, inp):
            layers, sts, k_cache, v_cache = inp

            def inner(c, linp):
                layer, st = linp
                hn = rms_norm(c, layer["ln"], cfg.norm_eps)
                out, st2 = ssm_mod.mamba_decode_step(layer["mamba"], hn, st,
                                                     cfg)
                return c + out, st2
            h, sts2 = jax.lax.scan(inner, carry, (layers, sts))
            h, kc, vc = _decode_attention_block(shared, h, cfg, 0, index,
                                                k_cache, v_cache)
            return h, (sts2, kc, vc)
        x, (sts, ks, vs) = jax.lax.scan(
            group, x, (params["layers"], state["ssm_layers"], state["k"],
                       state["v"]))
        new_state["ssm_layers"] = sts
        new_state["k"], new_state["v"] = ks, vs
    else:
        windows = jnp.asarray(layer_windows(cfg))

        def body(carry, inp):
            layer, window, k_cache, v_cache = inp
            h, kc, vc = _decode_attention_block(layer, carry, cfg, window,
                                                index, k_cache, v_cache)
            return h, (kc, vc)
        x, (ks, vs) = jax.lax.scan(
            body, x, (params["layers"], windows, state["k"], state["v"]))
        new_state["k"], new_state["v"] = ks, vs

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    new_state["index"] = index + 1
    return x @ head, new_state
