"""Model substrate: the 10 assigned LM-family architectures plus the
paper's own evaluation networks (ResNet/Inception/GNMT/Transformer/DCGAN).
"""

from repro.models.config import ModelConfig, ShapeConfig, SHAPES
from repro.models.transformer import (init_params, forward, loss_fn,
                                      init_decode_state, prefill, decode_step)
