"""Shared tooling for the §Perf hillclimb: lower a cell, list the largest
collectives/tensors with op_name metadata, and report roofline deltas.

Importing this module has NO side effects: the ``XLA_FLAGS`` host-device
override and the ``src/`` path bootstrap only happen inside
:func:`setup_environment`, which the entry points call lazily.  That
keeps the module safe to import from long-lived processes (the serving
workers, the what-if optimizer's search scaffolding) that must not have
their environment or ``sys.path`` mutated by a tooling import.
"""

import os
import re
import sys
from pathlib import Path

_DT = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4, "pred": 1,
       "s8": 1, "u8": 1, "f64": 8, "s64": 8, "u64": 8}

_READY = False


def setup_environment(host_devices: int = 512) -> None:
    """Prepare this process for mesh lowering (idempotent, explicit).

    Sets ``XLA_FLAGS`` so the CPU backend exposes enough host devices to
    build production-shaped meshes, and makes ``repro`` importable when
    the caller has not set PYTHONPATH.  Must run before jax initializes
    its backends — :func:`lower_cell` calls it first thing, so script
    users need not call it themselves.  ``XLA_FLAGS`` already set in the
    environment wins (``setdefault``), as does an already-importable
    ``repro`` (no path is inserted)."""
    global _READY
    if _READY:
        return
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={host_devices}")
    try:
        import repro  # noqa: F401  (already importable: leave sys.path alone)
    except ImportError:
        sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
    _READY = True


def lower_cell(arch, shape_name, cfg_override=None, multi_pod=False):
    setup_environment()
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.launch import specs
    from repro.launch.mesh import make_production_mesh
    from repro.models.config import SHAPES
    from repro.parallel import ctx, sharding
    from repro.train.optim import adamw

    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    opt = adamw()
    profile = getattr(cfg, "sharding_profile", "2d")
    if shape.mode != "train" and getattr(cfg, "sharding_profile_serve", ""):
        profile = cfg.sharding_profile_serve
    if profile == "dp" and shape.global_batch % chips != 0:
        # pure DP requires global_batch >= devices (e.g. batch 256 on the
        # 512-chip 2-pod mesh): fall back to 2D FSDPxTP
        profile = "2d"
    with ctx.use_mesh(mesh):
        if profile == "dp":
            ctx.set_batch_axes(("pod", "data", "model"))
            ctx.set_seq_axes(())
        elif profile == "sp":
            ctx.set_batch_axes(("pod", "data"))
            ctx.set_seq_axes(("model",))
        else:
            ctx.set_batch_axes(("pod", "data"))
            ctx.set_seq_axes(())
        batch_abs = specs.input_specs(cfg, shape)
        batch_sh = sharding.tree_shardings(
            sharding.batch_specs(batch_abs, mesh, profile=profile), mesh)
        step = specs.step_fn_for(cfg, shape, opt, profile)
        if shape.mode == "train":
            state_abs = specs.abstract_train_state(cfg, opt)
            state_sh = sharding.tree_shardings(
                sharding.param_specs(state_abs, mesh, profile), mesh)
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                              out_shardings=(state_sh, None),
                              donate_argnums=(0,)
                              ).lower(state_abs, batch_abs)
        elif shape.mode == "prefill":
            params_abs = specs.abstract_params(cfg)
            params_sh = sharding.tree_shardings(
                sharding.param_specs(params_abs, mesh, profile), mesh)
            lowered = jax.jit(step, in_shardings=(params_sh, batch_sh)
                              ).lower(params_abs, batch_abs)
        else:
            params_abs = specs.abstract_params(cfg)
            params_sh = sharding.tree_shardings(
                sharding.param_specs(params_abs, mesh, profile), mesh)
            dstate_abs = specs.abstract_decode_state(
                cfg, shape.global_batch, shape.seq_len)
            dstate_sh = sharding.tree_shardings(
                sharding.cache_specs(dstate_abs, mesh, shape.global_batch),
                mesh)
            lowered = jax.jit(step,
                              in_shardings=(params_sh, batch_sh, dstate_sh),
                              out_shardings=(None, dstate_sh),
                              donate_argnums=(2,)
                              ).lower(params_abs, batch_abs, dstate_abs)
        compiled = lowered.compile()
    return compiled, chips


def report(compiled, chips, label=""):
    setup_environment()
    from repro.launch import hlo_analysis

    roof = hlo_analysis.analyze(compiled, chips)
    d = roof.as_dict()
    print(f"[{label}] compute {d['compute_s']*1e3:.1f}ms "
          f"memory {d['memory_s']*1e3:.1f}ms "
          f"collective {d['collective_s']*1e3:.1f}ms -> {d['bound']}")
    print(f"  coll detail GiB: "
          f"{ {k: round(v/2**30,1) for k,v in d['collective_detail'].items()} }")
    try:
        mem = compiled.memory_analysis()
        print(f"  temp {mem.temp_size_in_bytes/2**30:.2f} GiB/device")
    except Exception:
        pass
    return roof


def top_collectives(compiled, n=12, while_weight=True):
    """The n largest collective instructions with op_name provenance."""
    setup_environment()
    from repro.launch import hlo_analysis

    text = compiled.as_text()
    mod = hlo_analysis.HloModule(text)
    rows = []
    # crude: scan all computations; weight by trip count of enclosing while
    weights = {}
    for name, lines in mod.computations.items():
        weights[name] = 1.0
    for name, lines in mod.computations.items():
        for line in lines:
            m = re.search(r"body=%?([\w.\-]+)", line)
            if m and "while(" in line:
                t = re.search(r"known_trip_count[^\d]*(\d+)", line)
                weights[m.group(1)] = float(t.group(1)) if t else 1.0
    for name, lines in mod.computations.items():
        w = weights.get(name, 1.0)
        for line in lines:
            m = re.search(
                r"=\s*(\([^)]*\)|\S+)\s+(all-gather|all-reduce|"
                r"reduce-scatter|all-to-all|collective-permute)\(", line)
            if not m:
                continue
            size = hlo_analysis._shape_list_bytes(m.group(1))
            op_name = ""
            om = re.search(r'op_name="([^"]*)"', line)
            if om:
                op_name = om.group(1)[-90:]
            rows.append((size * (w if while_weight else 1.0), size, w,
                         m.group(2), op_name))
    rows.sort(reverse=True)
    for total, size, w, op, op_name in rows[:n]:
        print(f"  {total/2**30:8.2f} GiB (= {size/2**20:7.1f} MiB x{w:4.0f}) "
              f"{op:<19} {op_name}")
    return rows
