"""Assemble EXPERIMENTS.md from the dry-run artifacts + perf log + bench
results.  Run after the optimized sweep completes:

    PYTHONPATH=src python experiments/make_experiments_md.py
"""

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.core.devices import (ROOFLINE_HBM_BW, ROOFLINE_LINK_BW,
                                ROOFLINE_PEAK_FLOPS)


def load(d):
    cells = {}
    for p in sorted((ROOT / "experiments" / d).glob("*.json")):
        c = json.loads(p.read_text())
        cells[(c["arch"], c["shape"], c["multi_pod"])] = c
    return cells


def fmt_ms(s):
    return f"{s * 1e3:,.0f}"


def roofline_fraction(c):
    ideal = c["model_flops"] / c["chips"] / ROOFLINE_PEAK_FLOPS
    return ideal / c["step_s"] if c.get("step_s") else 0.0


def main():
    opt = load("dryrun")
    base = load("dryrun_baseline")
    ok = {k: v for k, v in opt.items() if v.get("status") == "ok"}
    skipped = [v for v in opt.values() if v.get("status") == "skipped"]
    errors = [v for v in opt.values() if v.get("status") == "error"]

    out = []
    out.append("""# EXPERIMENTS

Reproduction of *Habitat: A Runtime-Based Computational Performance
Predictor for DNN Training* (USENIX ATC'21) as a multi-pod JAX framework.
Hardware target: TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI); 256-chip (16x16) production pod and 2-pod (2x16x16, 512 chip) mesh.
This container is CPU-only: dry-runs lower+compile the SPMD programs
against 512 placeholder host devices; roofline terms come from the
compiled per-device HLO via a while-loop-aware analyzer
(src/repro/launch/hlo_analysis.py) because XLA's `cost_analysis()` counts
scan bodies once (verified: a 28-step scanned matmul reports 1/28th of its
flops). Collective bytes = summed result shapes of all-gather/all-reduce/
reduce-scatter/all-to-all/collective-permute, loop-weighted.

## §Reproduction — the paper's own claims

Run: `PYTHONPATH=src python -m benchmarks.run` (bench_output.txt).

| claim (paper) | paper | this repo (bench_output.txt) |
|---|---|---|
| end-to-end prediction error, 30 pairs x 5 models (Fig. 3) | 11.8% avg (9.5-13.4% per model) | **10.4% avg** (8.1-14.7% per model) |
| Habitat error on DCGAN from T4 (Fig. 1) | 10.2% | **10.1%** |
| peak-FLOPS heuristic on DCGAN (Fig. 1) | 42.5-64.9% | 18.2% avg / 26.0% max (our simulated fleet has a narrower device spread than real GPUs; Habitat still clearly better) |
| per-op MLP-op error (Fig. 4) | 18.0% | 32.9% per-op (uncorrelated; end-to-end sums are in band) |
| wave-scaled op error / importance split (Fig. 4) | 29.8%, ~95% of ops | 27.6%, 88.7% of ops |
| MLP depth/width: deeper/wider better, knee ~2^9 (Fig. 5) | qualitative | reproduced (fig5 grid) |
| case 1: V100 fastest, T4 best samples/$ (Sec. 5.3.1) | correct ranking, 10.7% err | **both rankings correct**, 12.7% err |
| case 2: V100 not worth it over 2080Ti (Sec. 5.3.2) | ~1.1x, 7.7% err | **verdict correct** (pred 1.05x vs gt 1.00x), 10.4% err |
| Habitat+Daydream mixed precision (Sec. 6.1.2) | 16.1% | 22.4% |
| batch-size extrapolation (Sec. 6.1.3) | — | 13.2% at 2x-beyond-traced batch |

Ground truth for accelerator timings is the calibrated analytical device
simulator (DESIGN.md §2) — deliberately richer than wave scaling (wave
quantization, per-generation algorithm selection, launch overheads), so
prediction error is structural, not cosmetic.  The host-CPU wallclock
measurement path (`OperationTracker(measure="wallclock")`) is exercised in
tests.

## §Dry-run — multi-pod compile feasibility
""")
    n1 = sum(1 for k in ok if not k[2])
    n2 = sum(1 for k in ok if k[2])
    out.append(f"Cells compiled OK: **{n1} single-pod + {n2} multi-pod**; "
               f"{len(skipped)} skipped (long_500k on the 7 pure "
               f"full-attention archs, per assignment; gemma3/mamba2/zamba2 "
               f"run it); {len(errors)} errors.\n")
    out.append("Per-cell artifacts (memory_analysis, cost_analysis, "
               "collective schedule): `experiments/dryrun/*.json`; "
               "baseline (pre-§Perf) artifacts: "
               "`experiments/dryrun_baseline/`.\n")

    out.append("\n## §Roofline — per (arch x shape), single-pod 16x16\n")
    out.append("compute = HLO_FLOPs/(chips x 197e12); memory = HLO_bytes/"
               "(chips x 819e9); collective = collective_bytes/(chips x "
               "50e9). `useful` = MODEL_FLOPS (6·N_active·D train, "
               "2·N_active·tokens inference) / total HLO FLOPs. "
               "`frac` = ideal-compute-time / dominant term.\n")
    out.append("\n| arch | shape | compute ms | memory ms | collective ms |"
               " bound | useful | frac | next lever |\n|---|---|--:|--:|--:"
               "|---|--:|--:|---|\n")
    lever = {
        "memory": "fuse via Pallas flash/SSD kernels (VMEM-resident blocks)",
        "collective": "shard_map manual a2a / ring attention",
        "compute": "MXU-aligned tiling",
    }
    for (arch, shape, mp), c in sorted(ok.items()):
        if mp:
            continue
        out.append(
            f"| {arch} | {shape} | {fmt_ms(c['compute_s'])} | "
            f"{fmt_ms(c['memory_s'])} | {fmt_ms(c['collective_s'])} | "
            f"{c['bound']} | {c['useful_flops_ratio']:.2f} | "
            f"{roofline_fraction(c):.3f} | {lever[c['bound']]} |\n")

    out.append("\nMulti-pod (2x16x16) deltas: the pod axis joins the batch/"
               "FSDP axes; cross-pod gradient reduction rides DCN. "
               "Per-cell numbers in the 2pod artifacts.\n")
    out.append("\n| arch | shape | 1pod step ms | 2pod step ms | "
               "2pod bound |\n|---|---|--:|--:|---|\n")
    for (arch, shape, mp), c in sorted(ok.items()):
        if mp:
            continue
        c2 = ok.get((arch, shape, True))
        if not c2:
            continue
        out.append(f"| {arch} | {shape} | {fmt_ms(c['step_s'])} | "
                   f"{fmt_ms(c2['step_s'])} | {c2['bound']} |\n")

    out.append("""
HBM residency (memory_analysis, donation-aware): every cell fits 16 GiB
/chip except three marginal ones — dbrx-132b prefill_32k 1pod (20.2 GiB;
fits on the 2-pod mesh), minitron-4b train_4k 1pod under the fast dp
profile (19.4 GiB; the 2d profile fits at ~3x the step time), and
internvl2-2b train_4k 2pod (17.9 GiB; accum x4 would fit).  All three have
in-tree fitting configurations; the reported profiles maximize the §Perf
objective.

Notes on accounting: the memory term is an **unfused upper bound** — the
analyzer charges every HLO instruction's operands+outputs as HBM traffic.
On the TPU target, the Pallas kernels (kernels/) keep flash-attention
score blocks and SSD chunk states VMEM-resident, which removes the largest
single contributor to the memory term for attention/SSM models.  The
`useful` column quantifies remat/dispatch overhead (values < 1 mean the
compiled program executes more FLOPs than the 6·N·D model).

## §Perf — baseline → optimized (three hillclimbed cells)

Full hypothesis → change → measure → confirmed/refuted log:
**experiments/perf_log.md** (9 iterations, 6 confirmed, 3 refuted).
Summary of the dominant-term trajectory:

| cell | why chosen | dominant term baseline | optimized | gain |
|---|---|--:|--:|--:|
""")
    picks = [
        ("qwen3-0.6b", "train_4k", "representative (paper's technique "
         "traces this exact step)"),
        ("dbrx-132b", "train_4k", "worst roofline fraction AND most "
         "collective-bound"),
        ("gemma3-1b", "prefill_32k", "collective-bound inference"),
    ]
    for arch, shape, why in picks:
        b = base.get((arch, shape, False), {})
        o = ok.get((arch, shape, False), {})
        if b.get("status") == "ok" and o:
            bs, os_ = b["step_s"], o["step_s"]
            out.append(f"| {arch} x {shape} | {why} | {fmt_ms(bs)} ms "
                       f"({b['bound']}) | {fmt_ms(os_)} ms ({o['bound']}) |"
                       f" {bs / os_:.1f}x |\n")
    out.append("""
Changes that landed framework-wide from the hillclimb (all cells benefit;
the baseline/ artifacts predate them): causal block-skipping flash
attention, locality-grouped E-major MoE dispatch, per-arch sharding
profiles (2d / dp / sp + serve override), interior activation sharding
constraints, dbrx gradient accumulation (fits 16 GB HBM: temp 12.8 GiB).

**Paper-faithful vs beyond-paper (predictor axis)** — the reproduction
baseline (paper's exact method: Eq. 2 wave scaling + per-kind MLPs on the
paper's sampling ranges) vs our extended version (per-kernel backward-
shape coverage, log-domain training, optional Eq. 1 + dispatch-overhead
modelling): end-to-end error 38.1% → **8.7%** on the 5-model eval
(paper's own result: 11.8%).  Both are reported by benchmarks/run.py.

## §Scale-out design (1000+ nodes)

* elastic restore across mesh sizes (tests/test_sharding.py: 8→4 devices),
* deterministic data skip-ahead + async sharded checkpoints + crash-resume
  bitwise-identical training (tests/test_fault_tolerance.py),
* straggler watchdog (EWMA, compile-step aware),
* int8+error-feedback gradient compression (3.7x wire volume) for DCN
  cross-pod reduction,
* the pod axis generalizes: make_production_mesh(multi_pod=True) is
  (pods, 16, 16); nothing in the sharding rules assumes 2 pods.
""")
    (ROOT / "EXPERIMENTS.md").write_text("".join(out))
    print(f"wrote EXPERIMENTS.md: {len(ok)} ok cells, {len(errors)} errors")


if __name__ == "__main__":
    main()
